//! Minimal emit-only JSON document model.
//!
//! Results files (`results/*.json`) and the artifact manifest reader need
//! only a small subset of JSON; with `serde` unavailable offline this module
//! provides an owned value tree with a compact writer and a tolerant reader
//! sufficient for `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// An owned JSON value (ordered object keys for stable output).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// String value.
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Numeric value.
    pub fn n(v: f64) -> Json {
        Json::Num(v)
    }

    /// Integer convenience.
    pub fn i(v: i64) -> Json {
        Json::Num(v as f64)
    }

    /// Get an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Get a string field.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Get a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Get a non-negative integer (a number with no fractional part that
    /// fits `u64` exactly).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9e15 => Some(*n as u64),
            _ => None,
        }
    }

    /// Get a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Get an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (tolerant subset: no \u beyond BMP escapes
    /// needed by our manifests). Returns `None` on malformed input.
    pub fn parse(text: &str) -> Option<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i == p.b.len() {
            Some(v)
        } else {
            None
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Option<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Some(())
        } else {
            None
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Option<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Some(v)
        } else {
            None
        }
    }

    fn value(&mut self) -> Option<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Option<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Some(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self.b.get(self.i..self.i + 4)?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
                            self.i += 4;
                            out.push(char::from_u32(code)?);
                        }
                        _ => return None,
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c)?;
                        let slice = self.b.get(start..start + len)?;
                        out.push_str(std::str::from_utf8(slice).ok()?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Option<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()?
            .parse::<f64>()
            .ok()
            .map(Json::Num)
    }

    fn array(&mut self) -> Option<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Some(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Some(Json::Arr(items));
                }
                _ => return None,
            }
        }
    }

    fn object(&mut self) -> Option<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Some(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Some(Json::Obj(map));
                }
                _ => return None,
            }
        }
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let doc = Json::obj(vec![
            ("name", Json::s("fig3")),
            ("tops", Json::n(233.4)),
            ("series", Json::arr(vec![Json::i(1), Json::i(2)])),
            ("ok", Json::Bool(true)),
        ]);
        let text = doc.pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, doc);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"artifacts": [{"name": "cnn_fwd", "path": "cnn_fwd.hlo.txt",
                       "inputs": [[1,3,32,32]], "dtype": "f32"}]}"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("cnn_fwd"));
    }

    #[test]
    fn escapes() {
        let doc = Json::s("a\"b\\c\nd");
        let text = doc.compact();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_none());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::i(42).compact(), "42");
        assert_eq!(Json::n(1.5).compact(), "1.5");
    }

    #[test]
    fn unicode_string() {
        let doc = Json::s("θ≈π");
        assert_eq!(Json::parse(&doc.compact()).unwrap(), doc);
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::n(f64::INFINITY).compact(), "null");
    }

    #[test]
    fn typed_accessors() {
        assert_eq!(Json::i(1024).as_u64(), Some(1024));
        assert_eq!(Json::n(1.5).as_u64(), None);
        assert_eq!(Json::n(-1.0).as_u64(), None);
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::i(1).as_bool(), None);
    }

    #[test]
    fn float_round_trip_is_exact() {
        // The writer's shortest-round-trip formatting is what makes
        // cached sweep results byte-identical to recomputed ones.
        for &x in &[0.1, 1.0 / 3.0, 2.33e14, 1.34e17, 6.4e-15] {
            let text = Json::n(x).compact();
            assert_eq!(Json::parse(&text).unwrap().as_f64(), Some(x), "{text}");
        }
    }
}
