//! Cost extraction: pick the cheapest representative node per e-class.
//!
//! Costs mirror the accounting [`crate::pim::isa::Program`] already
//! tracks — per-opcode cycles from [`GateSet::costs`], exactly like
//! `Program::cycles_for` — plus a logic-gate count as tie-break. Illegal
//! opcodes (MAJ in a NOR family, NOR in a MAJ family) carry the same
//! [`crate::pim::gates::ILLEGAL_COST`] sentinel the cost tables use, so
//! a choice that would not validate can never beat a legal one.
//!
//! Extraction is the usual bottom-up fixpoint (the same shape as the
//! egg-netlist-synthesizer's cell-library extractor): a class's cost is
//! the cheapest `node cost + Σ child class costs` over its nodes,
//! relaxed until nothing improves. Iteration is over the deterministic
//! [`ClassIndex`], so ties resolve identically on every run.

use std::collections::BTreeMap;

use crate::pim::gates::GateSet;
use crate::synth::egraph::{EGraph, Id, Node};

/// Lexicographic (cycles, logic gates): fewer cycles wins, gates break ties.
pub type Cost = (u64, u64);

/// Costs at or above this are considered unrealizable for the gate set.
pub const INFEASIBLE: u64 = u64::MAX / 8;

/// The intrinsic cost of one node (children excluded) under a gate set.
pub fn node_cost(set: GateSet, node: &Node) -> Cost {
    let c = set.costs();
    match node {
        Node::Const(_) => (c.set, 0),
        Node::Var(_) => (0, 0),
        Node::Not(_) => (c.not, 1),
        Node::Nor2(_) => (c.nor2, 1),
        Node::Nor3(_) => (c.nor3, 1),
        Node::Maj3(_) => (c.maj3, 1),
    }
}

fn add(a: Cost, b: Cost) -> Cost {
    (a.0.saturating_add(b.0), a.1.saturating_add(b.1))
}

/// The per-class choices of a completed extraction.
#[derive(Clone, Debug)]
pub struct Extraction {
    choice: BTreeMap<Id, (Cost, Node)>,
}

impl Extraction {
    /// The chosen node for a class (key must be a representative id).
    pub fn node(&self, class: Id) -> Option<&Node> {
        self.choice.get(&class).map(|(_, n)| n)
    }

    /// The accumulated tree cost of a class under the chosen nodes.
    pub fn cost(&self, class: Id) -> Option<Cost> {
        self.choice.get(&class).map(|(c, _)| *c)
    }
}

/// Extract cheapest implementations for `roots` (and everything they
/// reach). Returns `None` if any root is unrealizable on this gate set —
/// the caller falls back to the original program.
pub fn extract(g: &EGraph, set: GateSet, roots: &[Id]) -> Option<Extraction> {
    let idx = g.class_index();
    let mut best: BTreeMap<Id, (Cost, Node)> = BTreeMap::new();
    loop {
        let mut changed = false;
        for (root, nodes) in idx.iter() {
            for node in nodes {
                let mut cost = node_cost(set, node);
                let mut resolved = true;
                for &child in node.children() {
                    match best.get(&g.find(child)) {
                        Some((c, _)) => cost = add(cost, *c),
                        None => {
                            resolved = false;
                            break;
                        }
                    }
                }
                if !resolved {
                    continue;
                }
                // Strict improvement only: at equal cost the first node
                // found (class-index order) sticks, deterministically.
                let improves = best.get(&root).map_or(true, |(c, _)| cost < *c);
                if improves {
                    best.insert(root, (cost, *node));
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    for &r in roots {
        let (cost, _) = best.get(&g.find(r))?;
        if cost.0 >= INFEASIBLE {
            return None;
        }
    }
    Some(Extraction { choice: best })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::rules;

    #[test]
    fn extracts_var_for_double_negation() {
        let mut g = EGraph::new();
        let x = g.add(Node::Var(0));
        let nx = g.add(Node::Not(x));
        let nnx = g.add(Node::Not(nx));
        rules::saturate(&mut g, rules::for_set(GateSet::MemristiveNor), 8, 100_000);
        let ex = extract(&g, GateSet::MemristiveNor, &[nnx]).unwrap();
        assert_eq!(ex.cost(g.find(nnx)), Some((0, 0)), "!!x is just the input column");
        assert!(matches!(ex.node(g.find(nnx)), Some(Node::Var(0))));
    }

    #[test]
    fn prefers_wide_nor_over_or_chain() {
        // nor(!nor(a,b), c): 3 gates / 6 cycles as written, 1 gate / 2
        // cycles once nor3-form has run.
        let mut g = EGraph::new();
        let a = g.add(Node::Var(0));
        let b = g.add(Node::Var(1));
        let c = g.add(Node::Var(2));
        let nab = g.add(Node::Nor2([a, b]));
        let or_ab = g.add(Node::Not(nab));
        let root = g.add(Node::Nor2([or_ab, c]));
        rules::saturate(&mut g, rules::for_set(GateSet::MemristiveNor), 8, 100_000);
        let ex = extract(&g, GateSet::MemristiveNor, &[root]).unwrap();
        assert_eq!(ex.cost(g.find(root)), Some((2, 1)));
        assert!(matches!(ex.node(g.find(root)), Some(Node::Nor3(_))));
    }

    #[test]
    fn illegal_ops_are_unrealizable() {
        // A MAJ3 over fresh vars cannot be realized on the NOR set (no
        // rule rewrites a general majority into NORs).
        let mut g = EGraph::new();
        let a = g.add(Node::Var(0));
        let b = g.add(Node::Var(1));
        let c = g.add(Node::Var(2));
        let root = g.add(Node::Maj3([a, b, c]));
        rules::saturate(&mut g, rules::for_set(GateSet::MemristiveNor), 8, 100_000);
        assert!(extract(&g, GateSet::MemristiveNor, &[root]).is_none());
        // ...but it is realizable in DRAM.
        let ex = extract(&g, GateSet::DramMaj, &[root]).unwrap();
        assert_eq!(ex.cost(g.find(root)), Some((4, 1)));
    }
}
