//! Physical gate sets and their cycle/energy cost models.
//!
//! The paper evaluates two concrete digital-PIM technologies (Table 1):
//!
//! * **Memristive stateful logic** (MAGIC-style): crossbars of memristors
//!   where applying fixed bitline voltages executes a NOR into an output
//!   memristor in every row simultaneously. Each gate requires the output
//!   device to be *initialized* to logic '1' first, so one logical gate
//!   costs two crossbar cycles. Parameters from Table 1: 1024×1024 arrays,
//!   6.4 fJ/gate, 333 MHz.
//! * **In-DRAM computing** (SIMDRAM-style): triple-row activation performs
//!   a majority-of-three; negation uses dual-contact cells; row-copy uses
//!   activate-activate-precharge (AAP). Parameters from Table 1:
//!   65536×1024 arrays, 391 fJ/gate, 0.5 MHz.
//!
//! Cycle costs are calibrated so that re-derived program latencies land on
//! the paper's published throughputs (DESIGN.md §4 "Model calibration"):
//! memristive 32-bit fixed addition = 9·N gates × 2 cycles = 576 cycles
//! ⇒ 233 TOPS at 48 GB / 333 MHz, matching Figure 3; the DRAM MAJ/NOT
//! full adder (3 MAJ + 2 NOT) at the costs below lands at the ~575-cycle
//! 32-bit addition the paper's 0.35 TOPS implies.
//!
//! Beyond the paper's pair, [`GateSet::Arch`] points at a declarative
//! [`crate::archdef::ArchDef`] — the same cost-model surface
//! (costs/dims/clock/power) backed by data instead of a `match`, which is
//! how `pim:ambit`, `pim:imply`, `pim:felix`, … enter every downstream
//! model. Code that shapes *programs* (builder, validator, optimizer
//! rules) dispatches on [`LogicFamily`], never on the concrete variant,
//! so any definition compiles and executes bit-exactly.

use crate::archdef::ArchDef;

/// Cycle-cost sentinel for opcodes a gate set cannot execute. Any program
/// containing one prices beyond [`crate::synth::extract::INFEASIBLE`], so
/// cost extraction refuses to select it and `validate_for` rejects it.
pub const ILLEGAL_COST: u64 = u64::MAX / 4;

/// The opcode vocabulary a gate set compiles to. This is what the
/// microcode builder, program validator, and rewrite-rule selection
/// dispatch on — two architectures of the same family differ only in
/// costs, never in which programs are legal.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LogicFamily {
    /// NOR-complete stateful logic (MAGIC, IMPLY, FELIX, …): NOR2/NOR3/NOT.
    Nor,
    /// In-DRAM majority logic (Ambit, SIMDRAM, PLiM, …): MAJ3/NOT/COPY.
    Maj,
}

/// Which physical gate set a program targets.
///
/// `Eq`/`Hash`/`Debug` are hand-implemented over [`GateSet::key_name`]:
/// arch definitions are interned (`&'static`), uniquely named, and carry
/// `f64`s, so the name *is* the identity — which keeps `GateSet` a valid
/// memoization key for the synth cache and sweep cache paths.
#[derive(Clone, Copy)]
pub enum GateSet {
    /// Memristive stateful logic (MAGIC NOR/NOT).
    MemristiveNor,
    /// In-DRAM majority/NOT (SIMDRAM-style).
    DramMaj,
    /// A declaratively defined architecture (see [`crate::archdef`]).
    Arch(&'static ArchDef),
}

impl PartialEq for GateSet {
    fn eq(&self, other: &Self) -> bool {
        self.key_name() == other.key_name()
    }
}

impl Eq for GateSet {}

impl std::hash::Hash for GateSet {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.key_name().hash(state);
    }
}

impl std::fmt::Debug for GateSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GateSet::MemristiveNor => write!(f, "MemristiveNor"),
            GateSet::DramMaj => write!(f, "DramMaj"),
            GateSet::Arch(d) => write!(f, "Arch({})", d.name),
        }
    }
}

/// Per-opcode cycle costs and per-row-gate energies for a gate set.
#[derive(Clone, Copy, Debug)]
pub struct GateCosts {
    /// Cycles for a two-input NOR (memristive: init + execute).
    pub nor2: u64,
    /// Cycles for a three-input NOR (MAGIC executes it in the same
    /// init+execute envelope as NOR2; serial families like IMPLY pay
    /// extra implication steps).
    pub nor3: u64,
    /// Cycles for a NOT.
    pub not: u64,
    /// Cycles for a majority-of-three (DRAM: row-copy AAPs + TRA).
    pub maj3: u64,
    /// Cycles for a row copy.
    pub copy: u64,
    /// Cycles for a column initialization.
    pub set: u64,
    /// Energy per *row* per logic gate, joules (Table 1 "Gate Energy").
    pub gate_energy_j: f64,
    /// Energy per row per data-movement op, joules (modeled equal to a
    /// gate: a SET/AAP stresses the same devices/bitlines once).
    pub move_energy_j: f64,
}

impl GateSet {
    /// The cost model for this gate set.
    pub fn costs(self) -> GateCosts {
        match self {
            // MAGIC: every gate = 1 output-init cycle + 1 execution cycle.
            GateSet::MemristiveNor => GateCosts {
                nor2: 2,
                nor3: 2,
                not: 2,
                maj3: ILLEGAL_COST, // illegal; validate_for catches it
                copy: 4,            // built from two NOTs when needed
                set: 1,
                gate_energy_j: 6.4e-15,
                move_energy_j: 6.4e-15,
            },
            // SIMDRAM: MAJ = 4 activation cycles (operand AAP copies into
            // the TRA group + the triple activation); NOT = 3 (AAP to the
            // dual-contact row and back); COPY = 2 (one AAP pair).
            GateSet::DramMaj => GateCosts {
                nor2: ILLEGAL_COST, // illegal
                nor3: ILLEGAL_COST,
                not: 3,
                maj3: 4,
                copy: 2,
                set: 1,
                gate_energy_j: 391e-15,
                move_energy_j: 391e-15,
            },
            GateSet::Arch(d) => d.costs,
        }
    }

    /// The opcode vocabulary this set's programs are built from.
    pub fn family(self) -> LogicFamily {
        match self {
            GateSet::MemristiveNor => LogicFamily::Nor,
            GateSet::DramMaj => LogicFamily::Maj,
            GateSet::Arch(d) => d.family,
        }
    }

    /// Crossbar geometry (rows, cols) from Table 1 / the arch definition.
    pub fn crossbar_dims(self) -> (u64, u64) {
        match self {
            GateSet::MemristiveNor => (1024, 1024),
            GateSet::DramMaj => (65536, 1024),
            GateSet::Arch(d) => (d.rows, d.cols),
        }
    }

    /// Clock frequency in Hz from Table 1 / the arch definition.
    pub fn clock_hz(self) -> f64 {
        match self {
            GateSet::MemristiveNor => 333e6,
            GateSet::DramMaj => 0.5e6,
            GateSet::Arch(d) => d.clock_hz,
        }
    }

    /// Max power in watts from Table 1 (full duty cycle at max
    /// parallelism); declarative archs either state it or derive it the
    /// same way (see [`ArchDef::resolved_max_power_w`]).
    pub fn max_power_w(self) -> f64 {
        match self {
            GateSet::MemristiveNor => 860.0,
            GateSet::DramMaj => 80.0,
            GateSet::Arch(d) => d.resolved_max_power_w(),
        }
    }

    /// Human-readable name used in reports.
    pub fn name(self) -> &'static str {
        match self {
            GateSet::MemristiveNor => "Memristive PIM",
            GateSet::DramMaj => "DRAM PIM",
            GateSet::Arch(d) => &d.display,
        }
    }

    /// Machine name: the backend-id segment (`pim:KEY`), the campaign
    /// `arch.set` key, and the identity `Eq`/`Hash` reduce to. The legacy
    /// pair keeps its pre-DSL keys; arch defs use their registry name.
    pub fn key_name(self) -> &'static str {
        match self {
            GateSet::MemristiveNor => "memristive",
            GateSet::DramMaj => "dram",
            GateSet::Arch(d) => &d.name,
        }
    }

    /// The paper's two gate sets, for sweeps over the published tables.
    pub fn all() -> [GateSet; 2] {
        [GateSet::MemristiveNor, GateSet::DramMaj]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memristive_gate_is_two_cycles() {
        let c = GateSet::MemristiveNor.costs();
        assert_eq!(c.nor2, 2);
        assert_eq!(c.nor3, 2);
        assert_eq!(c.not, 2);
    }

    #[test]
    fn dram_full_adder_calibration() {
        // FA = 3 MAJ + 2 NOT must cost ~18 cycles so that a 32-bit ripple
        // adder lands near the paper-derived ~575 cycles (0.35 TOPS).
        let c = GateSet::DramMaj.costs();
        let fa = 3 * c.maj3 + 2 * c.not;
        assert_eq!(fa, 18);
        let add32 = 32 * fa;
        assert!((512..=640).contains(&add32), "add32={add32}");
    }

    #[test]
    fn table1_parameters() {
        assert_eq!(GateSet::MemristiveNor.crossbar_dims(), (1024, 1024));
        assert_eq!(GateSet::DramMaj.crossbar_dims(), (65536, 1024));
        assert_eq!(GateSet::MemristiveNor.clock_hz(), 333e6);
        assert_eq!(GateSet::DramMaj.clock_hz(), 0.5e6);
        assert_eq!(GateSet::MemristiveNor.max_power_w(), 860.0);
        assert_eq!(GateSet::DramMaj.max_power_w(), 80.0);
        assert!((GateSet::MemristiveNor.costs().gate_energy_j - 6.4e-15).abs() < 1e-20);
        assert!((GateSet::DramMaj.costs().gate_energy_j - 391e-15).abs() < 1e-18);
    }

    #[test]
    fn families_and_key_names() {
        assert_eq!(GateSet::MemristiveNor.family(), LogicFamily::Nor);
        assert_eq!(GateSet::DramMaj.family(), LogicFamily::Maj);
        assert_eq!(GateSet::MemristiveNor.key_name(), "memristive");
        assert_eq!(GateSet::DramMaj.key_name(), "dram");
    }

    #[test]
    fn arch_identity_is_the_name() {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let felix = crate::archdef::lookup("felix").unwrap();
        let ambit = crate::archdef::lookup("ambit").unwrap();
        assert_eq!(felix, crate::archdef::lookup("felix").unwrap());
        assert_ne!(felix, ambit);
        assert_ne!(felix, GateSet::MemristiveNor);
        let h = |s: GateSet| {
            let mut hasher = DefaultHasher::new();
            s.hash(&mut hasher);
            hasher.finish()
        };
        assert_eq!(h(felix), h(crate::archdef::lookup("felix").unwrap()));
        assert_eq!(format!("{felix:?}"), "Arch(felix)");
        assert_eq!(format!("{:?}", GateSet::DramMaj), "DramMaj");
    }
}
