//! Integration tests for the sweep-campaign engine: registry equivalence
//! (the acceptance bar: `sweep fig4` == the Fig. 4 registry numbers),
//! cache hit/miss behavior, and byte-identical streamed output across
//! worker counts and across cache/recompute runs.

use std::fs;
use std::path::PathBuf;

use convpim::coordinator::{self, Ctx};
use convpim::gpumodel::{GpuSpec, Roofline};
use convpim::metrics;
use convpim::pim::arch::PimArch;
use convpim::pim::fixed::FixedOp;
use convpim::pim::gates::GateSet;
use convpim::pim::matpim::NumFmt;
use convpim::pim::softfloat::Format;
use convpim::sweep::{
    run_points, Campaign, OutputFormat, PointResult, ResultCache, Streamer,
};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "convpim_sweep_it_{tag}_{}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// A small heterogeneous campaign touching every workload kind (cheap
/// formats only, so the test stays fast).
fn mixed_campaign() -> Campaign {
    Campaign::from_json_text(
        r#"{
          "name": "mixed",
          "archs": [{"set": "memristive"}],
          "formats": ["fixed16"],
          "workloads": [
            {"kind": "elementwise", "op": "add"},
            {"kind": "matmul", "n": 8},
            {"kind": "cnn", "model": "alexnet", "training": false},
            {"kind": "attention-decode", "seq": 128}
          ],
          "gpus": [{"gpu": "a6000", "mode": "experimental"}]
        }"#,
    )
    .unwrap()
}

/// Render a campaign's stream at a given worker count / cache setting.
fn render(
    campaign: &Campaign,
    format: OutputFormat,
    jobs: usize,
    cache: Option<&ResultCache>,
) -> (String, usize, usize) {
    let points = campaign.points();
    let mut streamer = Streamer::new(format, Vec::new()).unwrap();
    let outcome = run_points(&points, jobs, cache, &mut |_, r| {
        streamer.emit(r).unwrap();
        true
    });
    assert_eq!(outcome.failures(), 0);
    let bytes = streamer.finish().unwrap();
    (
        String::from_utf8(bytes).unwrap(),
        outcome.hits,
        outcome.computed,
    )
}

#[test]
fn sweep_fig4_reproduces_registry_numbers_exactly() {
    // The acceptance bar: the sweep engine's fig4 campaign must produce
    // the same values as the registry's Fig. 4 path. Both go through
    // metrics::cc_point, so equality is exact, not approximate.
    let points = Campaign::builtin("fig4").unwrap().points();
    let results: Vec<PointResult> = points.iter().map(|p| p.eval().unwrap()).collect();

    let arch = PimArch::paper(GateSet::MemristiveNor);
    let gpu = Roofline::new(GpuSpec::a6000());
    let formats = [
        NumFmt::Fixed(8),
        NumFmt::Fixed(16),
        NumFmt::Fixed(32),
        NumFmt::Float(Format::FP16),
        NumFmt::Float(Format::FP32),
        NumFmt::Float(Format::FP64),
    ];
    let expect = metrics::cc_sweep(
        GateSet::MemristiveNor,
        &arch,
        &gpu,
        &formats,
        &FixedOp::all(),
    );

    assert_eq!(results.len(), expect.len());
    for (r, e) in results.iter().zip(&expect) {
        assert_eq!(r.format, e.fmt.name());
        assert_eq!(r.workload, format!("elementwise-{}", e.op.name()));
        assert_eq!(r.cc, Some(e.cc), "{}", r.label);
        assert_eq!(r.pim, e.pim_ops, "{}", r.label);
        assert_eq!(r.gpu_tp, e.gpu_ops, "{}", r.label);
        assert_eq!(r.improvement(), e.improvement(), "{}", r.label);
    }
}

#[test]
fn fig4_experiment_table_matches_sweep_engine() {
    // The ported registry experiment delegates to the same campaign; its
    // JSON payload must carry the sweep's improvement values.
    let mut ctx = Ctx::analytic();
    let exp = coordinator::run_experiment("fig4", &mut ctx).unwrap();
    let rows = exp.json.get("points").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 24);

    let mut results: Vec<PointResult> = Campaign::builtin("fig4")
        .unwrap()
        .points()
        .iter()
        .map(|p| p.eval().unwrap())
        .collect();
    results.sort_by(|a, b| a.cc.partial_cmp(&b.cc).unwrap());
    for (row, r) in rows.iter().zip(&results) {
        assert_eq!(
            row.get("improvement").unwrap().as_f64().unwrap(),
            r.improvement()
        );
        assert_eq!(row.get("cc").unwrap().as_f64(), r.cc);
    }
}

#[test]
fn second_run_of_unchanged_campaign_computes_zero_points() {
    let dir = temp_dir("hits");
    let cache = ResultCache::new(&dir);
    let campaign = mixed_campaign();
    let n = campaign.points().len();

    let (csv1, hits1, computed1) = render(&campaign, OutputFormat::Csv, 1, Some(&cache));
    assert_eq!((hits1, computed1), (0, n), "cold cache must compute all");

    let (csv2, hits2, computed2) = render(&campaign, OutputFormat::Csv, 1, Some(&cache));
    assert_eq!(
        (hits2, computed2),
        (n, 0),
        "an unchanged campaign re-run must execute zero points"
    );
    // Cache-served output is byte-identical to the computed run.
    assert_eq!(csv1, csv2);

    // A changed point misses while unchanged ones still hit.
    let mut changed = campaign.clone();
    changed.workloads.push(convpim::sweep::WorkloadSpec::Matmul(16));
    let points = changed.points();
    let outcome = run_points(&points, 1, Some(&cache), &mut |_, _| true);
    assert_eq!(outcome.failures(), 0);
    assert_eq!(outcome.hits, n);
    assert_eq!(outcome.computed, 1);

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn streamed_output_is_byte_identical_across_jobs() {
    let campaign = Campaign::builtin("fig5").unwrap();
    let (csv1, _, _) = render(&campaign, OutputFormat::Csv, 1, None);
    let (csv8, _, _) = render(&campaign, OutputFormat::Csv, 8, None);
    assert_eq!(csv1, csv8, "CSV must not depend on worker count");
    assert_eq!(csv1.lines().count(), campaign.len() + 1, "header + one row per point");

    let (jl1, _, _) = render(&campaign, OutputFormat::Jsonl, 1, None);
    let (jl8, _, _) = render(&campaign, OutputFormat::Jsonl, 8, None);
    assert_eq!(jl1, jl8, "JSONL must not depend on worker count");
    assert_eq!(jl1.lines().count(), campaign.len());
}

#[test]
fn cache_hits_preserve_byte_identical_output_across_jobs() {
    // The full acceptance chain: cold run at --jobs 8, warm run at
    // --jobs 1 — different scheduling, different cache states, same bytes.
    let dir = temp_dir("warmcold");
    let cache = ResultCache::new(&dir);
    let campaign = mixed_campaign();
    let (cold, _, computed) = render(&campaign, OutputFormat::Jsonl, 8, Some(&cache));
    assert_eq!(computed, campaign.len());
    let (warm, hits, _) = render(&campaign, OutputFormat::Jsonl, 1, Some(&cache));
    assert_eq!(hits, campaign.len());
    assert_eq!(cold, warm);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn deterministic_point_ordering_under_parallel_execution() {
    let points = Campaign::builtin("sens-dims").unwrap().points();
    let mut emitted: Vec<usize> = Vec::new();
    let outcome = run_points(&points, 4, None, &mut |i, _| {
        emitted.push(i);
        true
    });
    assert_eq!(outcome.failures(), 0);
    assert_eq!(emitted, (0..points.len()).collect::<Vec<_>>());
    // Results vector is in input order too.
    for (p, r) in points.iter().zip(&outcome.results) {
        assert_eq!(r.as_ref().unwrap().label, p.label());
    }
}
