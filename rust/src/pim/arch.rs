//! Architecture-scale digital-PIM performance and energy model.
//!
//! Turns microcode cycle counts into the paper's system-level numbers.
//! The architecture is a memory of total size `mem_bytes` built from
//! `rows × cols` crossbars that all operate in lockstep (the maximal
//! parallelism the paper assumes): the bitwise throughput is
//! `total_rows × clock`, and an arithmetic routine of `C` cycles executes
//! at `total_rows × clock / C` operations per second (§2.2, §3).
//!
//! Power is the paper's "maximal parallelism at full duty cycle" model
//! (Table 1): every row of every crossbar switches one device per cycle.

use super::gates::GateSet;
use super::isa::Program;

/// A sized digital-PIM system (one Table 1 column).
#[derive(Clone, Copy, Debug)]
pub struct PimArch {
    /// Technology / gate set.
    pub set: GateSet,
    /// Rows per crossbar.
    pub rows: u64,
    /// Columns per crossbar.
    pub cols: u64,
    /// Total memory size in bytes (paper: 48 GB to match the A6000).
    pub mem_bytes: u64,
    /// Clock frequency, Hz.
    pub clock_hz: f64,
    /// Max power, W (full duty cycle at max parallelism).
    pub max_power_w: f64,
}

/// The paper's 48 GB memory size.
pub const PAPER_MEM_BYTES: u64 = 48 * (1 << 30);

impl PimArch {
    /// Table 1 configuration for a gate set (48 GB system).
    pub fn paper(set: GateSet) -> Self {
        let (rows, cols) = set.crossbar_dims();
        PimArch {
            set,
            rows,
            cols,
            mem_bytes: PAPER_MEM_BYTES,
            clock_hz: set.clock_hz(),
            max_power_w: set.max_power_w(),
        }
    }

    /// Same technology with different crossbar dimensions (sensitivity
    /// study S3); power scales with total row parallelism.
    pub fn with_dims(set: GateSet, rows: u64, cols: u64) -> Self {
        let base = PimArch::paper(set);
        let scale = Self::rows_total_for(base.mem_bytes, rows, cols)
            as f64
            / base.total_rows() as f64;
        PimArch {
            rows,
            cols,
            max_power_w: base.max_power_w * scale,
            ..base
        }
    }

    fn rows_total_for(mem_bytes: u64, rows: u64, cols: u64) -> u64 {
        let bits = mem_bytes as u128 * 8;
        let per_xbar = rows as u128 * cols as u128;
        (bits / per_xbar) as u64 * rows
    }

    /// Number of crossbars in the memory.
    pub fn num_crossbars(&self) -> u64 {
        (self.mem_bytes as u128 * 8 / (self.rows as u128 * self.cols as u128)) as u64
    }

    /// Total row parallelism `R` (rows × crossbars).
    pub fn total_rows(&self) -> u64 {
        self.num_crossbars() * self.rows
    }

    /// Peak bitwise gate throughput (column-gates × rows per second).
    pub fn gate_throughput(&self) -> f64 {
        self.total_rows() as f64 * self.clock_hz
    }

    /// Vectored-arithmetic throughput for a routine of `cycles` latency:
    /// one result per row per program execution (§3's bit-serial
    /// element-parallel model).
    pub fn throughput_ops(&self, cycles: u64) -> f64 {
        assert!(cycles > 0);
        self.gate_throughput() / cycles as f64
    }

    /// Throughput for a compiled program.
    pub fn throughput(&self, prog: &Program) -> f64 {
        self.throughput_ops(prog.cycles())
    }

    /// Energy per element-wise operation in joules: the program's gates,
    /// one per row, at the technology's per-gate energy (one row computes
    /// one element).
    pub fn energy_per_op_j(&self, prog: &Program) -> f64 {
        prog.energy_j(1)
    }

    /// Average power when running `prog` continuously at max parallelism:
    /// `ops/s × energy/op` (bounded above by `max_power_w`; the Table 1
    /// max-power figures are derived exactly this way for the elementary
    /// gate, so long programs with Set/Copy overheads land slightly
    /// below).
    pub fn avg_power_w(&self, prog: &Program) -> f64 {
        self.throughput(prog) * self.energy_per_op_j(prog)
    }

    /// Throughput per watt (the paper's energy-efficiency metric) using
    /// the max-power normalization of §2.2.
    pub fn throughput_per_watt(&self, prog: &Program) -> f64 {
        self.throughput(prog) / self.max_power_w
    }

    /// How many vector elements (rows) the memory can process at once for
    /// an operation whose row footprint is `row_bits` bits (operands +
    /// result + scratch). The paper's model assumes the full memory is
    /// available; a row computes one element as long as its bit-field fits
    /// the crossbar width.
    pub fn elements_in_flight(&self, row_bits: u64) -> u64 {
        if row_bits > self.cols {
            0
        } else {
            self.total_rows()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::fixed::{self, FixedOp};

    #[test]
    fn paper_memristive_row_parallelism() {
        let a = PimArch::paper(GateSet::MemristiveNor);
        // 48 GB / (1024×1024 bits) = 393,216 crossbars.
        assert_eq!(a.num_crossbars(), 393_216);
        assert_eq!(a.total_rows(), 393_216 * 1024);
        // Gate throughput = R × f ≈ 1.34e17.
        let gt = a.gate_throughput();
        assert!((1.3e17..1.4e17).contains(&gt), "{gt:e}");
    }

    #[test]
    fn paper_dram_row_parallelism_equals_memristive() {
        // Same memory size and row width => same total rows (DESIGN §4).
        let m = PimArch::paper(GateSet::MemristiveNor);
        let d = PimArch::paper(GateSet::DramMaj);
        assert_eq!(m.total_rows(), d.total_rows());
    }

    #[test]
    fn fig3_fixed32_add_anchor() {
        // The headline 233 TOPS for memristive fixed-32 addition.
        let a = PimArch::paper(GateSet::MemristiveNor);
        let p = fixed::program(FixedOp::Add, 32, GateSet::MemristiveNor);
        let tops = a.throughput(&p) / 1e12;
        assert!(
            (200.0..260.0).contains(&tops),
            "fixed32 add = {tops} TOPS, paper says 233"
        );
    }

    #[test]
    fn fig3_dram_fixed32_add_anchor() {
        let a = PimArch::paper(GateSet::DramMaj);
        let p = fixed::program(FixedOp::Add, 32, GateSet::DramMaj);
        let tops = a.throughput(&p) / 1e12;
        assert!(
            (0.25..0.45).contains(&tops),
            "dram fixed32 add = {tops} TOPS, paper says 0.35"
        );
    }

    #[test]
    fn dims_sensitivity_scales_parallelism() {
        let small = PimArch::with_dims(GateSet::MemristiveNor, 256, 1024);
        let big = PimArch::with_dims(GateSet::MemristiveNor, 4096, 1024);
        // Same memory: 4096-row arrays have the same total rows (rows ×
        // crossbars is memory/cols-invariant) — the knob that matters is
        // column width.
        assert_eq!(small.total_rows(), big.total_rows());
        let narrow = PimArch::with_dims(GateSet::MemristiveNor, 1024, 512);
        let wide = PimArch::with_dims(GateSet::MemristiveNor, 1024, 2048);
        assert_eq!(
            narrow.total_rows(),
            2 * PimArch::paper(GateSet::MemristiveNor).total_rows(),
            "halving the column width (1024 -> 512) at fixed memory size must exactly \
             double total row parallelism (R = mem_bits / cols)"
        );
        assert!(narrow.total_rows() > wide.total_rows());
    }

    #[test]
    fn avg_power_below_max() {
        let a = PimArch::paper(GateSet::MemristiveNor);
        let p = fixed::program(FixedOp::Add, 32, GateSet::MemristiveNor);
        let w = a.avg_power_w(&p);
        assert!(w > 0.0 && w <= a.max_power_w * 1.05, "avg power {w} W");
    }
}
