//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. loads the AOT artifacts (L2 JAX graphs + L1 Pallas kernels, lowered
//!    once by `make artifacts`) through the PJRT runtime;
//! 2. **trains** the micro-CNN for a few hundred steps through the
//!    compiled train-step executable, logging the loss curve (the
//!    training-systems validation workload);
//! 3. cross-checks the Pallas crossbar kernel against the native Rust PIM
//!    simulator bit-for-bit;
//! 4. runs a bit-exact PIM arithmetic sweep;
//! 5. regenerates every paper table/figure (analytic + measured) into
//!    `results/`.
//!
//! Run with: `cargo run --release --example e2e_full_eval`
//! (recorded in docs/EXPERIMENTS.md §E2E).

use convpim::coordinator::{self, report, Ctx};
use convpim::pim::fixed::{self, FixedLayout, FixedOp};
use convpim::pim::gates::GateSet;
use convpim::pim::xbar::Crossbar;
use convpim::runtime::{Engine, TensorData};
use convpim::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    println!("=== ConvPIM end-to-end evaluation ===\n");

    // ---- 1. runtime up ----------------------------------------------------
    let mut engine = Engine::new()?;
    println!(
        "[1] PJRT platform `{}`, {} artifacts",
        engine.platform(),
        engine.manifest().artifacts.len()
    );

    // ---- 2. real training run through the AOT train step -------------------
    let steps = std::env::var("E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300usize);
    let exe = engine.load("cnn_alexnet_train_step")?;
    let mut inputs = exe.synth_inputs(99);
    let n_params = inputs.len() - 2;
    for t in inputs.iter_mut().take(n_params) {
        if let TensorData::F32(v) = t {
            for x in v.iter_mut() {
                *x *= 0.1; // sane init scale
            }
        }
    }
    // Fixed synthetic batch (learnable task: memorize 8 labels).
    println!("[2] training micro-CNN for {steps} steps through the compiled train step…");
    let mut first = None;
    let mut last = 0f32;
    let train_t = std::time::Instant::now();
    for step in 0..steps {
        let out = exe.run(&inputs)?;
        let loss = out.last().unwrap().as_f32()[0];
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
        for (i, t) in out.into_iter().take(n_params).enumerate() {
            inputs[i] = t;
        }
        if step % 50 == 0 || step == steps - 1 {
            println!("    step {step:>4}  loss {loss:.4}");
        }
    }
    let train_secs = train_t.elapsed().as_secs_f64();
    let first = first.unwrap();
    println!(
        "    loss {first:.4} -> {last:.4} over {steps} steps ({:.1} steps/s); descended: {}",
        steps as f64 / train_secs,
        last < first
    );
    anyhow::ensure!(last < first, "training did not reduce the loss");

    // ---- 3. cross-layer consistency: Pallas kernel vs native simulator -----
    println!("[3] cross-checking the Pallas crossbar kernel vs the native simulator…");
    let exe = engine.load("pim_fixed_add16")?;
    let spec = &exe.spec.inputs[0];
    let (words, width) = (spec.shape[0], spec.shape[1]);
    let rows = words * 32;
    let mut rng = Rng::new(5);
    let u = rng.vec_bits(rows, 16);
    let v = rng.vec_bits(rows, 16);
    let mut state = vec![0u32; words * width];
    for (r, (&uu, &vv)) in u.iter().zip(&v).enumerate() {
        for k in 0..16 {
            if (uu >> k) & 1 == 1 {
                state[(r / 32) * width + k] |= 1 << (r % 32);
            }
            if (vv >> k) & 1 == 1 {
                state[(r / 32) * width + 16 + k] |= 1 << (r % 32);
            }
        }
    }
    let out = exe.run(&[TensorData::U32(state)])?;
    let packed = out[0].as_u32();
    let prog = fixed::program(FixedOp::Add, 16, GateSet::MemristiveNor);
    let lay = FixedLayout::new(FixedOp::Add, 16);
    let mut xbar = Crossbar::new(rows, prog.width() as usize);
    fixed::load_operands(&mut xbar, &lay, &u, &v);
    xbar.execute(&prog);
    let native = fixed::read_result(&xbar, &lay, rows);
    for r in 0..rows {
        let mut z = 0u64;
        for k in 0..16 {
            if (packed[(r / 32) * width + 32 + k] >> (r % 32)) & 1 == 1 {
                z |= 1 << k;
            }
        }
        anyhow::ensure!(z == native[r] && z == ((u[r] + v[r]) & 0xFFFF), "row {r}");
    }
    println!("    {} rows bit-identical across Pallas/XLA and the native simulator", rows);

    // ---- 4 + 5. full evaluation -------------------------------------------
    println!("[4] running the full experiment registry (analytic + measured)…");
    let mut ctx = Ctx::new(true);
    let out_dir = std::path::PathBuf::from(
        std::env::var("E2E_OUT").unwrap_or_else(|_| "results".into()),
    );
    let mut results = Vec::new();
    for id in coordinator::all_ids() {
        let r = coordinator::run_experiment(id, &mut ctx)?;
        println!("    {id}: {} table(s), {} note(s)", r.sections.len(), r.notes.len());
        report::write_result(&out_dir, &r)?;
        results.push(r);
    }
    report::write_report(&out_dir, &results)?;
    println!(
        "\nE2E complete in {:.1}s -> {}/REPORT.md",
        t0.elapsed().as_secs_f64(),
        out_dir.display()
    );
    Ok(())
}
