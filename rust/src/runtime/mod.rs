//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs once at build time (`make artifacts`); this module makes
//! the Rust binary self-contained afterwards: it reads
//! `artifacts/manifest.json`, loads each `*.hlo.txt` (HLO **text** — the
//! 0.5.1-safe interchange, see `python/compile/aot.py`), compiles it on
//! the PJRT CPU client, and executes it with typed literals. The
//! coordinator uses it for the *measured* experiment series (Fig 3/5/6/7
//! testbed-scale numbers) and for the cross-layer consistency check
//! (the Pallas crossbar kernel vs the native simulator, bit for bit).

pub mod artifact;

// The real engine needs the external `xla` crate (PJRT bindings), which the
// offline registry does not carry. Without the `pjrt` feature a stub with
// the identical API compiles instead: `Engine::new()` reports that measured
// execution is unavailable and every caller degrades to the analytic path.
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use engine::{Engine, Executable, TensorData, TimedRun};
