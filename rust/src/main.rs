//! `convpim` — the evaluation CLI.
//!
//! Every subcommand is a thin adapter over the unified evaluation
//! service ([`convpim::service`]): it builds a typed
//! [`EvalRequest`], submits it to an [`EvalService`], and prints the
//! [`EvalResponse`](convpim::service::EvalResponse)'s exact stdout bytes
//! — so the CLI surface and the daemon/library surface are one code
//! path. Subcommands:
//!
//! * `run [ids…|all] [--out results] [--fast] [--no-measure]` — execute
//!   experiments (paper tables/figures + sensitivity studies) and write
//!   reports.
//! * `sweep <campaign.json|builtin>` — expand a declarative sweep
//!   campaign (builtin `fig4`/`fig5`/`sens-dims`/`conv-exec`/`net-exec` or a JSON
//!   grid file) into points, execute them concurrently with
//!   content-addressed result caching, and stream table/CSV/JSONL output.
//! * `exec-conv --layer model:sel [--scale N]` — execute a down-scaled
//!   model-zoo conv layer bit-exactly on the crossbar via im2col and
//!   cross-check the measured per-MAC cost against the analytic CNN
//!   model.
//! * `exec-net --model alexnet [--scale N] [--batch N]` — execute a whole
//!   down-scaled network end to end on the crossbar (conv/fc/pool/relu),
//!   verify every output bit-exactly, and report inter-layer data
//!   movement as its own cost bucket.
//! * `compare --workload NAME --backends ID[,ID...]` — evaluate one
//!   workload across N evaluation backends ([`convpim::backend`]) side
//!   by side: analytic PIM, executed crossbar, GPU rooflines.
//! * `validate [--rows N] [--seed S]` — bit-exact validation sweep of the
//!   arithmetic microcode on the crossbar simulator.
//! * `opt [--set S] [--ops add,mul] [--formats fixed8,...]` — run the
//!   equality-saturation microcode synthesizer over each op × format ×
//!   gate-set cell, print per-cell and cycles-per-MAC deltas against the
//!   hand-derived microcode, and write the `BENCH_microcode.json`
//!   artifact.
//! * `arch [--describe NAME] [--validate FILE] [--validate-builtins]
//!   [--bench]` — the declarative architecture registry
//!   ([`convpim::archdef`]): list/describe/validate `ArchDef` JSON
//!   definitions (builtin catalogue: ambit, simdram, imply, plim, felix,
//!   plus the Table-1 pair and its DSL twins) and write the
//!   cross-architecture `BENCH_archspace.json` experiment.
//! * `serve [--jobs N] [--listen ADDR]` — long-running JSONL daemon:
//!   one request per line, responses streamed in input order while
//!   executing concurrently on one warm two-tier cache. Default
//!   transport is stdin/stdout; `--listen` serves N concurrent TCP
//!   sessions with load-shedding (`--queue`) and a `stats` endpoint
//!   (see `docs/EXPERIMENTS.md` SERVE).
//! * `loadgen` — deterministic closed-loop load generator against a
//!   self-hosted (or `--addr` external) daemon; writes the
//!   `BENCH_serve.json` throughput/tail-latency artifact.
//! * `info` — system inventory: Table 1 parameters, artifact manifest,
//!   PJRT platform.
//! * `list` — available experiment ids and builtin sweep campaigns.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::Context as _;
use convpim::coordinator::report;
use convpim::service::{
    self, resolve_jobs, ConvExecSpec, EvalRequest, EvalResponse, EvalService, NetExecSpec,
    ResultCache, SetSel,
};
use convpim::sweep::campaign::fmt_from_name;
use convpim::sweep::{Campaign, OutputFormat, Streamer, WorkloadSpec};
use convpim::util::cli::Args;

const USAGE: &str = "\
convpim — reproduction of `ConvPIM: Evaluating Digital Processing-in-Memory
through Convolutional Neural Network Acceleration`

USAGE:
  convpim run [ids...|all] [--out DIR] [--fast] [--no-measure] [--seed N] [--jobs N]
              [--no-cache] [--cache-dir DIR]
  convpim sweep <campaign.json|builtin> [--jobs N] [--format table|csv|jsonl]
                [--no-cache] [--cache-dir DIR] [--out FILE]
  convpim exec-conv --layer MODEL:SEL [--scale N] [--fmt FMT] [--set memristive|dram|both]
                    [--seed N] [--rows N] [--no-cache] [--cache-dir DIR]
  convpim exec-net --model MODEL [--scale N] [--batch N] [--fmt FMT]
                   [--set memristive|dram|both] [--seed N] [--rows N]
                   [--no-cache] [--cache-dir DIR]
  convpim compare --workload NAME --backends ID[,ID...] [--fmt FMT]
                  [--no-cache] [--cache-dir DIR]
  convpim validate [--rows N] [--seed N]
  convpim opt [--set memristive|dram|both] [--ops add,mul]
              [--formats fixed8,fixed16,fp32] [--out FILE]
  convpim arch [--describe NAME] [--validate FILE] [--validate-builtins]
               [--bench] [--out FILE]
  convpim serve [--jobs N] [--no-cache] [--cache-dir DIR] [--mem-cache N]
                [--listen HOST:PORT [--queue N]]
  convpim loadgen [--addr HOST:PORT] [--clients N,N,...] [--requests N]
                  [--seed N] [--out FILE] [--jobs N] [--queue N]
                  [--mem-cache N] [--no-cache] [--cache-dir DIR]
  convpim info
  convpim list
  convpim help

Everything goes through one evaluation service: a subcommand builds a
typed request, the service evaluates it (concurrently on a thread pool,
with a content-addressed result cache), and the subcommand prints the
response. Deterministic results — analytic experiments, sweep points,
seeded conv executions — are cached under --cache-dir (default
target/sweep-cache, shared by run/sweep/exec-conv/serve), so an
unchanged re-run recomputes nothing; --no-cache bypasses the cache.

Experiments run concurrently on a thread pool by default. --jobs 0 (the
default) sizes to the pool, explicit values are clamped to the pool and
to the amount of work; set CONVPIM_THREADS=1 to make the whole process
serial. Analytic and bit-exact output is identical in every mode;
wall-clock *measured* series (pjrt builds with artifacts) are
timing-sensitive — use CONVPIM_THREADS=1 when measuring. Measured
results are never cached.

`sweep` expands a declarative campaign — a grid over PIM architectures,
number formats, workloads and GPU baselines — into points and executes
them concurrently with deterministic, input-ordered streaming output.
Campaign JSON schema: docs/EXPERIMENTS.md SWEEP.

`exec-conv` executes one model-zoo conv layer on the crossbar simulator
(down-scaled by --scale, default 8) via the im2col mapping and compares
the measured per-MAC cycle/gate cost against the analytic CNN model; the
output is verified bit-identical to a host reference. MODEL is one of the
zoo models (alexnet, googlenet, resnet50, vgg16); SEL is `convN` (the
N-th conv layer), a layer name, or a name prefix. FMT is fixed8|fixed16|
fixed32|fp16|fp32|fp64 (default: fixed8 and fp32). Exits nonzero if any
executed cell deviates from the model. See docs/EXPERIMENTS.md CONV.

`exec-net` executes a whole network end to end on the crossbar simulator
(down-scaled by --scale, default 16): conv and fc layers via the im2col
MAC microcode, pooling and ReLU as column-parallel compare/select
programs. Tiles are pipelined across layers and batch samples on the
thread pool — outputs are byte-identical at any worker count. Every
output is verified bit-exactly against a host reference, per-layer MAC
costs are cross-checked against the analytic CNN model, and inter-layer
data movement (staging cycles and bits) is reported as its own cost
bucket next to compute. MODEL is alexnet, lenet or vgg. Exits nonzero if any
cell fails verification. See docs/EXPERIMENTS.md NET-EXEC.

`compare` evaluates ONE workload across N evaluation backends side by
side — the paper's workload x platform matrix as one command. Backends
are named by registry id: pim:SET[@RxC] (the analytic architecture
model), pim-exec:SET[@RxC] (bit-exact seeded execution on the crossbar
simulator; conv-exec workloads only, fails on any measured-vs-analytic
deviation), gpu:NAME[:MODE[:DTYPE]] (datasheet rooflines). Workload
names: elementwise-OP, matmul-nN, cnn-MODEL[-train], decode-sN,
conv-exec-MODEL-cN-sM, net-exec-MODEL-sN. `convpim list` prints the
registered backends;
campaigns can add the same ids as a `backends` axis (EXPERIMENTS.md
COMPARE/SWEEP).

`opt` runs the equality-saturation microcode synthesizer (the library's
`synth` module) over every requested op x format x gate-set cell: each
hand-derived gate program is abstracted into an e-graph, saturated under
the gate set's boolean rewrite rules, re-extracted against the
cycles/gates cost model, lowered back to microcode and proven bit-exact
on the crossbar simulator before any number is reported. The table
prints baseline -> optimized cycles and gates per cell (an explicit
zero-delta line when the hand microcode is already optimal under the
rule set) plus the derived cycles-per-MAC deltas that drive the
`pim-opt:*` backends, and writes the BENCH_microcode.json artifact
(--out; schema: docs/EXPERIMENTS.md OPT).

`arch` is the declarative architecture registry: with no flags it lists
every registered ArchDef (the digital-PIM design space the pim:*
backends accept as SET names); --describe NAME prints one definition as
canonical JSON plus its derived max-power; --validate FILE parses an
ArchDef JSON document, checks its opcode vocabulary against its logic
family, registers it for this process and proves its compiled fixed8
add/mul microcode bit-exact on the crossbar simulator;
--validate-builtins runs the same proof over the whole builtin
catalogue; --bench evaluates every registered architecture analytically
on cnn-alexnet and writes the per-architecture cycles-per-MAC /
throughput artifact (--out, default BENCH_archspace.json; JSON schema:
docs/EXPERIMENTS.md ARCH).

`serve` reads one request JSON per line and answers one response JSON
per line, in input order, while executing concurrently — pipelined
clients share one warm cache and one pool. A malformed line gets a
structured error response; EOF exits 0. With --listen HOST:PORT the
daemon serves N concurrent TCP sessions multiplexed onto one service
(one two-tier cache: a shared in-memory LRU of --mem-cache entries,
default 256, in front of the disk cache): per-session ordering holds,
--queue N bounds admission daemon-wide (overload requests get a
structured `shed` response with a retry_after_ms hint; TCP default
32 x jobs, 0 = unbounded), any request may carry "deadline_ms", and
{"kind": "stats"} snapshots counters and latency percentiles. The TCP
daemon still exits when stdin closes. Wire schema: docs/EXPERIMENTS.md
SERVE.

`loadgen` measures the daemon: seeded mixed request classes
(experiment/sweep-point/compare/conv-exec/list/info) from closed-loop
clients at each --clients concurrency level, reporting rps, exact
client-side p50/p95/p99 latency, cache hit rate and shed rate per level
to --out (default BENCH_serve.json, schema: docs/EXPERIMENTS.md
LOADGEN). By default it self-hosts a daemon on 127.0.0.1:0 with the
given --jobs/--queue/cache flags; --addr targets a running daemon
instead. Exits nonzero (after writing) if any level degenerates.

EXPERIMENTS: table1 fig3 fig4 fig5 fig6 fig7 fig8 sens-gpu sens-fp16 sens-dims conv-exec
SWEEP CAMPAIGNS (builtin): fig4 fig5 sens-dims conv-exec net-exec
BACKENDS: {pim,pim-opt,pim-exec,pim-exec-net}:SET[@RxC]
          SET: memristive dram or any `convpim arch` name
               (nor simdram ambit imply plim felix ...)
          gpu:{a6000,a100,v100,rtx3090}:{experimental,theoretical}[:fp32|fp16|fp16-tensor]
";

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    if args.wants_help() || args.command.is_none() {
        print!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    let result = match args.command.as_deref().unwrap() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "exec-conv" => cmd_exec_conv(&args),
        "exec-net" => cmd_exec_net(&args),
        "compare" => cmd_compare(&args),
        "validate" => cmd_validate(&args),
        "opt" => cmd_opt(&args),
        "arch" => cmd_arch(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "info" => cmd_info(),
        "list" => cmd_list(),
        other => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Build the evaluation service from the shared `--jobs` / `--no-cache` /
/// `--cache-dir` flags (one resolution rule for `run`, `sweep`,
/// `exec-conv` and `serve`).
fn service_from(args: &Args) -> anyhow::Result<EvalService> {
    let cache = if args.switch("no-cache") {
        None
    } else {
        Some(ResultCache::new(
            args.flag("cache-dir", service::DEFAULT_CACHE_DIR),
        ))
    };
    let jobs = args.flag_usize("jobs", 0).map_err(anyhow::Error::msg)?;
    Ok(EvalService::new().with_cache(cache).with_jobs(jobs))
}

/// Turn a failed response into the error the CLI reports (the service
/// stores the `{e:#}`-formatted chain, so the rendering matches the
/// pre-service output).
fn response_error(resp: &EvalResponse) -> anyhow::Error {
    anyhow::Error::msg(
        resp.meta
            .error
            .clone()
            .unwrap_or_else(|| "evaluation failed".into()),
    )
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let ids: Vec<String> = if args.positional.is_empty()
        || args.positional.iter().any(|p| p == "all")
    {
        convpim::coordinator::all_ids().iter().map(|s| s.to_string()).collect()
    } else {
        args.positional.clone()
    };
    let out: PathBuf = args.flag("out", "results").into();
    let seed = args.flag_usize("seed", 0xC0FFEE).map_err(anyhow::Error::msg)? as u64;
    let analytic = args.switch("no-measure");
    let fast = args.switch("fast");
    let service = service_from(args)?;
    let jobs = resolve_jobs(service.jobs(), Some(ids.len()));
    let reqs: Vec<EvalRequest> = ids
        .iter()
        .map(|id| EvalRequest::Experiment {
            id: id.clone(),
            fast,
            analytic,
            seed,
        })
        .collect();

    let mut results = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    if jobs > 1 && ids.len() > 1 {
        eprintln!("running {} experiment(s) on {jobs} worker(s)…", ids.len());
        // Unlike the serial path (which fails fast), every experiment has
        // already run by the time results come back — so write everything
        // that succeeded before reporting the first failure, instead of
        // discarding computed work.
        for (id, resp) in ids.iter().zip(service.submit_batch(&reqs)) {
            if resp.meta.ok {
                print!("{}", resp.stdout);
                let r = resp
                    .to_experiment_result()
                    .expect("ok experiment responses reconstruct");
                report::write_result(&out, &r)?;
                results.push(r);
            } else {
                let e = response_error(&resp);
                eprintln!("error: {id}: {e:#}");
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    } else {
        for (id, req) in ids.iter().zip(&reqs) {
            eprintln!("running {id}…");
            let resp = service.submit(req);
            if !resp.meta.ok {
                return Err(response_error(&resp));
            }
            print!("{}", resp.stdout);
            let r = resp
                .to_experiment_result()
                .expect("ok experiment responses reconstruct");
            report::write_result(&out, &r)?;
            results.push(r);
        }
    }
    report::write_report(&out, &results)?;
    eprintln!("wrote {} experiment(s) to {}", results.len(), out.display());
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Expand a campaign (builtin name or JSON file) and execute it through
/// the service with caching and streaming output.
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let Some(spec) = args.positional.first() else {
        anyhow::bail!(
            "sweep needs a campaign: a builtin name ({}) or a path to a campaign .json \
             (schema: docs/EXPERIMENTS.md SWEEP)",
            Campaign::builtin_names().join(", ")
        );
    };
    let campaign = match Campaign::builtin(spec) {
        Some(c) => c,
        None => {
            let text = std::fs::read_to_string(spec).with_context(|| {
                format!(
                    "reading campaign `{spec}` (not a builtin; builtins: {})",
                    Campaign::builtin_names().join(", ")
                )
            })?;
            Campaign::from_json_text(&text)
                .map_err(|e| e.context(format!("parsing campaign file `{spec}`")))?
        }
    };
    let format = OutputFormat::parse(args.flag("format", "table")).map_err(anyhow::Error::msg)?;
    let service = service_from(args)?;

    let points = campaign.points();
    eprintln!(
        "sweep `{}`: {} point(s) on {} worker(s){}…",
        campaign.name,
        points.len(),
        resolve_jobs(service.jobs(), Some(points.len())),
        if service.cache().is_some() { "" } else { " (cache disabled)" }
    );
    let sink: Box<dyn std::io::Write + Send> = match args.flag_opt("out") {
        Some(path) => Box::new(std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {path}"))?,
        )),
        None => Box::new(std::io::stdout()),
    };
    let mut streamer = Streamer::new(format, sink)?;
    let t0 = std::time::Instant::now();
    // An output I/O error (broken pipe from `| head`, full disk on --out)
    // must not panic inside a pool worker holding the emit lock: record
    // the first error and return `false` so the engine cancels the
    // points that have not started yet, then settle up after the run.
    let mut write_err: Option<std::io::Error> = None;
    let outcome = service.run_campaign(&points, &mut |_, r| {
        if write_err.is_none() {
            if let Err(e) = streamer.emit(r) {
                write_err = Some(e);
            }
        }
        write_err.is_none()
    });
    // A closed downstream pipe is a normal way to stop a stream; any
    // other write error is fatal. Real evaluation failures are still
    // reported below in both cases.
    let pipe_closed = matches!(
        &write_err,
        Some(e) if e.kind() == std::io::ErrorKind::BrokenPipe
    );
    if let Some(e) = write_err {
        if !pipe_closed {
            return Err(anyhow::Error::from(e).context("writing sweep output"));
        }
    } else if let Err(e) = streamer.finish() {
        if e.kind() != std::io::ErrorKind::BrokenPipe {
            return Err(anyhow::Error::from(e).context("writing sweep output"));
        }
    }
    if !pipe_closed {
        eprintln!(
            "sweep `{}`: {} point(s) — {} cache hit(s), {} computed, {} failed, {} canceled — in {:.2}s",
            campaign.name,
            points.len(),
            outcome.hits,
            outcome.computed,
            outcome.failures(),
            outcome.canceled(),
            t0.elapsed().as_secs_f64()
        );
    }

    // A failed point never discards completed ones: everything that
    // succeeded has already been streamed; report failures afterwards
    // (skipping cancellation markers — those are a consequence of the
    // sink closing, not failures of the campaign).
    let mut first_err: Option<anyhow::Error> = None;
    for (p, r) in points.iter().zip(outcome.results) {
        if let Err(e) = r {
            if convpim::sweep::is_canceled(&e) {
                continue;
            }
            eprintln!("error: {}: {e:#}", p.label());
            if first_err.is_none() {
                first_err = Some(e);
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Execute one down-scaled model-zoo conv layer on the crossbar and
/// cross-check measured per-MAC cost against the analytic CNN model.
fn cmd_exec_conv(args: &Args) -> anyhow::Result<()> {
    let layer = args.flag_opt("layer").ok_or_else(|| {
        anyhow::Error::msg("exec-conv needs --layer MODEL:SEL (e.g. --layer alexnet:conv2)")
    })?;
    let scale = args.flag_usize("scale", 8).map_err(anyhow::Error::msg)?;
    // ConvSpec::scaled clamps 0 to 1 (full-size execution — effectively a
    // hang on a real layer), so reject it here; also refuse silent u32
    // truncation of absurd values.
    let scale = u32::try_from(scale)
        .ok()
        .filter(|&s| s >= 1)
        .ok_or_else(|| {
            anyhow::Error::msg(format!("--scale must be in 1..=u32::MAX, got {scale}"))
        })?;
    let seed = args.flag_usize("seed", 0xC0DE).map_err(anyhow::Error::msg)? as u64;
    let rows = args.flag_usize("rows", 0).map_err(anyhow::Error::msg)?;
    let set_name = args.flag("set", "both");
    let set = SetSel::from_name(set_name).ok_or_else(|| {
        anyhow::Error::msg(format!(
            "--set must be memristive|dram|both, got `{set_name}`"
        ))
    })?;
    let fmt = match args.flag_opt("fmt") {
        None => None,
        Some(name) => Some(fmt_from_name(name).ok_or_else(|| {
            anyhow::Error::msg(format!(
                "unknown format `{name}` (use fixed8|fixed16|fixed32|fp16|fp32|fp64)"
            ))
        })?),
    };

    let service = service_from(args)?;
    let resp = service.submit(&EvalRequest::ConvExec(ConvExecSpec {
        layer: layer.to_string(),
        scale,
        fmt,
        set,
        seed,
        rows,
    }));
    // A replayed verdict must never look like a fresh execution: say so
    // loudly (stderr, so stdout stays byte-identical to a computed run).
    if resp.meta.cache == convpim::service::CacheStatus::Hit {
        eprintln!(
            "exec-conv: verdict served from the result cache (no execution this run); \
             pass --no-cache to re-execute, e.g. after engine changes"
        );
    }
    // On a deviation the table still prints (that is the diagnostic)
    // before the nonzero exit.
    print!("{}", resp.stdout);
    match resp.meta.ok {
        true => Ok(()),
        false => Err(response_error(&resp)),
    }
}

/// Execute a whole down-scaled network end to end on the crossbar and
/// report compute vs inter-layer movement, verified bit-exactly.
fn cmd_exec_net(args: &Args) -> anyhow::Result<()> {
    let model = args.flag_opt("model").ok_or_else(|| {
        anyhow::Error::msg("exec-net needs --model MODEL (e.g. --model alexnet)")
    })?;
    let scale = args.flag_usize("scale", 16).map_err(anyhow::Error::msg)?;
    // Like exec-conv: scale 0 would silently execute the full-size
    // network (effectively a hang), so reject it up front.
    let scale = u32::try_from(scale)
        .ok()
        .filter(|&s| s >= 1)
        .ok_or_else(|| {
            anyhow::Error::msg(format!("--scale must be in 1..=u32::MAX, got {scale}"))
        })?;
    let batch = args.flag_usize("batch", 1).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        (1..=1024).contains(&batch),
        "--batch must be in 1..=1024, got {batch}"
    );
    let seed = args.flag_usize("seed", 0xC0DE).map_err(anyhow::Error::msg)? as u64;
    let rows = args.flag_usize("rows", 0).map_err(anyhow::Error::msg)?;
    let set_name = args.flag("set", "both");
    let set = SetSel::from_name(set_name).ok_or_else(|| {
        anyhow::Error::msg(format!(
            "--set must be memristive|dram|both, got `{set_name}`"
        ))
    })?;
    let fmt = match args.flag_opt("fmt") {
        None => None,
        Some(name) => Some(fmt_from_name(name).ok_or_else(|| {
            anyhow::Error::msg(format!(
                "unknown format `{name}` (use fixed8|fixed16|fixed32|fp16|fp32|fp64)"
            ))
        })?),
    };

    let service = service_from(args)?;
    let resp = service.submit(&EvalRequest::NetExec(NetExecSpec {
        model: model.to_string(),
        scale,
        batch,
        fmt,
        set,
        seed,
        rows,
    }));
    // A replayed verdict must never look like a fresh execution.
    if resp.meta.cache == convpim::service::CacheStatus::Hit {
        eprintln!(
            "exec-net: verdict served from the result cache (no execution this run); \
             pass --no-cache to re-execute, e.g. after engine changes"
        );
    }
    // On a verification failure the table still prints (that is the
    // diagnostic) before the nonzero exit.
    print!("{}", resp.stdout);
    match resp.meta.ok {
        true => Ok(()),
        false => Err(response_error(&resp)),
    }
}

/// Evaluate one workload across N evaluation backends side by side (the
/// workload × platform matrix as one command).
fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    const WORKLOAD_GRAMMAR: &str =
        "elementwise-OP | matmul-nN | cnn-MODEL[-train] | decode-sN | conv-exec-MODEL-cN-sM \
         | net-exec-MODEL-sN";
    let workload_name = args.flag_opt("workload").ok_or_else(|| {
        anyhow::Error::msg(format!(
            "compare needs --workload NAME (e.g. --workload cnn-alexnet; names: {WORKLOAD_GRAMMAR})"
        ))
    })?;
    let workload = WorkloadSpec::from_name(workload_name).ok_or_else(|| {
        anyhow::Error::msg(format!(
            "unknown workload `{workload_name}` (names: {WORKLOAD_GRAMMAR})"
        ))
    })?;
    let fmt_name = args.flag("fmt", "fp32");
    let fmt = fmt_from_name(fmt_name).ok_or_else(|| {
        anyhow::Error::msg(format!(
            "unknown format `{fmt_name}` (use fixed8|fixed16|fixed32|fp16|fp32|fp64)"
        ))
    })?;
    let backends_arg = args.flag_opt("backends").ok_or_else(|| {
        anyhow::Error::msg(
            "compare needs --backends ID[,ID...] (e.g. --backends \
             pim:memristive,gpu:a6000:experimental; `convpim list` shows registered ids)",
        )
    })?;
    let backends: Vec<String> = backends_arg
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!backends.is_empty(), "--backends needs at least one backend id");

    let service = service_from(args)?;
    let resp = service.submit(&EvalRequest::Compare {
        workload,
        fmt,
        backends,
    });
    // Like exec-conv: a replayed verdict must never look like a fresh
    // evaluation (pim-exec rows execute the simulator when computed).
    if resp.meta.cache == convpim::service::CacheStatus::Hit {
        eprintln!(
            "compare: served from the result cache (no evaluation this run); \
             pass --no-cache to re-evaluate"
        );
    }
    print!("{}", resp.stdout);
    match resp.meta.ok {
        true => Ok(()),
        false => Err(response_error(&resp)),
    }
}

/// Bit-exact validation sweep: every arithmetic routine on both gate sets
/// executed on the simulated crossbar against host arithmetic / softfloat.
fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    let rows = args.flag_usize("rows", 512).map_err(anyhow::Error::msg)?;
    let seed = args.flag_usize("seed", 7).map_err(anyhow::Error::msg)? as u64;
    // Validation is a purity check of the engine itself, so the CLI
    // always runs it for real rather than replaying a cached verdict.
    // (`exec-conv` *is* cached by default — its verdict is re-executed by
    // every sweep/registry conv-exec point and by CI on each source
    // change, and `--no-cache` forces re-execution — whereas `validate`
    // is the tool you reach for precisely when you suspect the engine,
    // when a cached PASS would be worthless.)
    let service = EvalService::new().with_cache(None);
    let resp = service.submit(&EvalRequest::Validate { rows, seed });
    print!("{}", resp.stdout);
    match resp.meta.ok {
        true => Ok(()),
        false => Err(response_error(&resp)),
    }
}

/// Run the equality-saturation microcode synthesizer over each
/// op × format × gate-set cell, report the per-cell and cycles-per-MAC
/// deltas against the hand-derived microcode, and write
/// `BENCH_microcode.json`.
fn cmd_opt(args: &Args) -> anyhow::Result<()> {
    use convpim::pim::fixed::FixedOp;
    use convpim::pim::gates::GateSet;
    use convpim::pim::matpim::{scalar_costs, NumFmt};
    use convpim::synth;
    use convpim::util::json::Json;

    // Short registry-style key ("memristive"/"dram"/an archdef name),
    // distinct from the display name GateSet::name() returns.
    fn set_key(set: GateSet) -> &'static str {
        set.key_name()
    }

    let set_name = args.flag("set", "both");
    let sel = SetSel::from_name(set_name).ok_or_else(|| {
        anyhow::Error::msg(format!(
            "--set must be memristive|dram|both, got `{set_name}`"
        ))
    })?;
    let sets = sel.sets();

    let ops_arg = args.flag("ops", "add,mul");
    let ops: Vec<FixedOp> = ops_arg
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| {
            FixedOp::all().into_iter().find(|op| op.name() == s).ok_or_else(|| {
                anyhow::Error::msg(format!("unknown op `{s}` (use add|sub|mul|div)"))
            })
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!ops.is_empty(), "--ops needs at least one op");

    let fmts_arg = args.flag("formats", "fixed8,fixed16,fp32");
    let fmts: Vec<NumFmt> = fmts_arg
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| {
            fmt_from_name(s).ok_or_else(|| {
                anyhow::Error::msg(format!(
                    "unknown format `{s}` (use fixed8|fixed16|fixed32|fp16|fp32|fp64)"
                ))
            })
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!fmts.is_empty(), "--formats needs at least one format");

    let out: PathBuf = args.flag("out", "BENCH_microcode.json").into();

    println!(
        "microcode synthesis — equality saturation over the bit-serial gate programs"
    );
    println!();
    println!(
        "{:<12} {:<5} {:<8} {:>9} {:>9} {:>9} {:>9}  {}",
        "set", "op", "format", "cyc base", "cyc opt", "gat base", "gat opt", "delta"
    );
    let mut cells = Vec::new();
    for &set in &sets {
        for &op in &ops {
            for &fmt in &fmts {
                let opt = synth::optimized_op_program(op, fmt, set);
                let s = &opt.stats;
                // The acceptance contract: either a strictly positive
                // improvement or an *explicit* zero-delta line — never a
                // silently absent cell.
                let delta = if s.cycles_delta() > 0 {
                    format!(
                        "-{} cycles (-{:.1}%)",
                        s.cycles_delta(),
                        100.0 * s.cycles_delta() as f64 / s.baseline_cycles as f64
                    )
                } else {
                    "zero delta (hand microcode already optimal under the rule set)"
                        .to_string()
                };
                println!(
                    "{:<12} {:<5} {:<8} {:>9} {:>9} {:>9} {:>9}  {}",
                    set_key(set),
                    op.name(),
                    fmt.name(),
                    s.baseline_cycles,
                    s.optimized_cycles,
                    s.baseline_gates,
                    s.optimized_gates,
                    delta
                );
                cells.push(Json::obj(vec![
                    ("set", Json::s(set_key(set))),
                    ("op", Json::s(op.name())),
                    ("fmt", Json::s(fmt.name())),
                    ("baseline_cycles", Json::i(s.baseline_cycles as i64)),
                    ("optimized_cycles", Json::i(s.optimized_cycles as i64)),
                    ("cycles_delta", Json::i(s.cycles_delta() as i64)),
                    ("baseline_gates", Json::i(s.baseline_gates as i64)),
                    ("optimized_gates", Json::i(s.optimized_gates as i64)),
                    ("egraph_nodes", Json::i(s.egraph_nodes as i64)),
                    ("egraph_classes", Json::i(s.egraph_classes as i64)),
                    ("peak_scratch", Json::i(s.peak_scratch as i64)),
                    ("improved", Json::Bool(s.improved)),
                ]));
            }
        }
    }

    // The MAC cost (one mul + one accumulate-add) is what every matmul /
    // CNN / decode schedule multiplies by, so its delta is the headline
    // number. Only meaningful when both constituent ops were requested.
    let mut macs = Vec::new();
    if ops.contains(&FixedOp::Add) && ops.contains(&FixedOp::Mul) {
        println!();
        println!("cycles per MAC (mul + accumulate add):");
        for &set in &sets {
            for &fmt in &fmts {
                let base = scalar_costs(fmt, set);
                let opt = synth::optimized_costs(fmt, set);
                let base_mac = base.add_cycles + base.mul_cycles;
                let opt_mac = opt.add_cycles + opt.mul_cycles;
                let saved = base_mac - opt_mac;
                let delta = if saved > 0 {
                    format!("-{saved} (-{:.1}%)", 100.0 * saved as f64 / base_mac as f64)
                } else {
                    "zero delta".to_string()
                };
                println!(
                    "  {:<12} {:<8} {:>9} -> {:<9} {}",
                    set_key(set),
                    fmt.name(),
                    base_mac,
                    opt_mac,
                    delta
                );
                macs.push(Json::obj(vec![
                    ("set", Json::s(set_key(set))),
                    ("fmt", Json::s(fmt.name())),
                    ("baseline_mac_cycles", Json::i(base_mac as i64)),
                    ("optimized_mac_cycles", Json::i(opt_mac as i64)),
                    ("mac_cycles_delta", Json::i(saved as i64)),
                ]));
            }
        }
    }

    let doc = Json::obj(vec![
        ("bench", Json::s("microcode")),
        ("schema", Json::i(1)),
        ("cells", Json::arr(cells)),
        ("mac", Json::arr(macs)),
    ]);
    std::fs::write(&out, format!("{}\n", doc.pretty()))
        .with_context(|| format!("writing {}", out.display()))?;
    eprintln!("opt: wrote {}", out.display());
    Ok(())
}

/// The declarative architecture registry: list / describe / validate
/// `ArchDef` JSON definitions and write the cross-architecture
/// `BENCH_archspace.json` experiment.
fn cmd_arch(args: &Args) -> anyhow::Result<()> {
    use convpim::archdef::{self, ArchDef};
    use convpim::backend::{self, Backend as _};
    use convpim::pim::gates::{GateSet, LogicFamily};
    use convpim::pim::matpim::{scalar_costs, NumFmt};
    use convpim::util::json::Json;

    // Registered sets in report order: the builtin catalogue first, then
    // anything registered later this process, alphabetically.
    fn registered() -> Vec<&'static str> {
        let mut names: Vec<&'static str> =
            archdef::builtins().iter().map(|d| d.name.as_str()).collect();
        for name in archdef::names() {
            let interned = archdef::def_named(&name).expect("registered name").name.as_str();
            if !names.contains(&interned) {
                names.push(interned);
            }
        }
        names
    }

    fn family_name(family: LogicFamily) -> &'static str {
        match family {
            LogicFamily::Nor => "nor",
            LogicFamily::Maj => "maj",
        }
    }

    // Prove a definition's compiled microcode bit-exact on the crossbar
    // simulator: fixed8 add (wrapping) and mul (full product) against
    // host arithmetic, over deterministic seeded operands.
    fn oracle_check(set: GateSet) -> anyhow::Result<()> {
        use convpim::pim::fixed::{self, FixedLayout, FixedOp};
        use convpim::pim::xbar::Crossbar;
        use convpim::util::rng::Rng;
        let mut rng = Rng::new(0xA12C);
        let n = 8u32;
        let rows = 96usize;
        let u = rng.vec_bits(rows, n);
        let v = rng.vec_bits(rows, n);
        for op in [FixedOp::Add, FixedOp::Mul] {
            let lay = FixedLayout::new(op, n);
            let prog = fixed::program(op, n, set);
            prog.validate_for(set)
                .map_err(|e| anyhow::Error::msg(format!("{}: {e}", set.key_name())))?;
            let mut x = Crossbar::new(rows, prog.width() as usize);
            fixed::load_operands(&mut x, &lay, &u, &v);
            x.execute(&prog);
            let z = fixed::read_result(&x, &lay, rows);
            for i in 0..rows {
                let expect = match op {
                    FixedOp::Add => u[i].wrapping_add(v[i]) & 0xFF,
                    _ => u[i] * v[i],
                };
                anyhow::ensure!(
                    z[i] == expect,
                    "{} {op:?}: row {i} executed {} but host arithmetic says {expect}",
                    set.key_name(),
                    z[i]
                );
            }
        }
        Ok(())
    }

    fn describe(def: &ArchDef) -> String {
        format!(
            "{} ({}-family, {}x{} @ {:.1} MHz, {:.1} fJ/gate, {:.0} W{})",
            def.display,
            family_name(def.family),
            def.rows,
            def.cols,
            def.clock_hz / 1e6,
            def.costs.gate_energy_j * 1e15,
            def.resolved_max_power_w(),
            if def.max_power_w.is_some() { "" } else { " derived" },
        )
    }

    if let Some(name) = args.flag_opt("describe") {
        let def = archdef::def_named(name).ok_or_else(|| {
            anyhow::Error::msg(format!(
                "unknown architecture `{name}` (registered: {})",
                archdef::names().join(", ")
            ))
        })?;
        println!("{}", def.to_json().pretty());
        eprintln!("arch {}: {}", def.name, describe(def));
        return Ok(());
    }

    if let Some(file) = args.flag_opt("validate") {
        let text = std::fs::read_to_string(file).with_context(|| format!("reading {file}"))?;
        let def = ArchDef::from_json_text(&text).with_context(|| format!("validating {file}"))?;
        let interned = archdef::register(def)?;
        let set = archdef::lookup(&interned.name).expect("just registered");
        oracle_check(set)?;
        println!(
            "arch {}: valid — {}; fixed8 add/mul bit-exact on the crossbar simulator",
            interned.name,
            describe(interned)
        );
        return Ok(());
    }

    if args.switch("validate-builtins") {
        for name in registered() {
            let def = archdef::def_named(name).expect("registered");
            def.validate()
                .with_context(|| format!("builtin `{name}` failed structural validation"))?;
            let set = archdef::lookup(name).expect("registered");
            oracle_check(set)?;
            println!("arch {name}: valid — {}; fixed8 add/mul bit-exact", describe(def));
        }
        return Ok(());
    }

    if args.switch("bench") {
        let out: PathBuf = args.flag("out", "BENCH_archspace.json").into();
        let workload = WorkloadSpec::from_name("cnn-alexnet").expect("builtin workload");
        let fmts = [NumFmt::Fixed(8), NumFmt::Float(convpim::pim::softfloat::Format::FP32)];
        println!("architecture design space — analytic cnn-alexnet, per-MAC microcode costs");
        println!();
        println!(
            "{:<12} {:<4} {:<8} {:>10} {:>10} {:>12} {:>12}",
            "arch", "fam", "fmt", "mac cyc", "mac gates", "img/s", "img/s/W"
        );
        let mut rows = Vec::new();
        for name in registered() {
            let def = archdef::def_named(name).expect("registered");
            let set = archdef::lookup(name).expect("registered");
            let mut fmt_rows = Vec::new();
            for &fmt in &fmts {
                let c = scalar_costs(fmt, set);
                let mac_cycles = c.mul_cycles + c.add_cycles;
                let mac_gates = c.mul_gates + c.add_gates;
                let est = backend::parse(&format!("pim:{name}"))?.evaluate(&workload, fmt)?;
                println!(
                    "{:<12} {:<4} {:<8} {:>10} {:>10} {:>12.3e} {:>12.3e}",
                    name,
                    family_name(def.family),
                    fmt.name(),
                    mac_cycles,
                    mac_gates,
                    est.throughput,
                    est.per_watt
                );
                fmt_rows.push(Json::obj(vec![
                    ("fmt", Json::s(fmt.name())),
                    ("mac_cycles", Json::i(mac_cycles as i64)),
                    ("mac_gates", Json::i(mac_gates as i64)),
                    ("throughput", Json::n(est.throughput)),
                    ("per_watt", Json::n(est.per_watt)),
                ]));
            }
            rows.push(Json::obj(vec![
                ("arch", Json::s(name)),
                ("family", Json::s(family_name(def.family))),
                ("rows", Json::i(def.rows as i64)),
                ("cols", Json::i(def.cols as i64)),
                ("clock_hz", Json::n(def.clock_hz)),
                ("gate_energy_j", Json::n(def.costs.gate_energy_j)),
                ("max_power_w", Json::n(def.resolved_max_power_w())),
                ("fmts", Json::arr(fmt_rows)),
            ]));
        }
        let doc = Json::obj(vec![
            ("bench", Json::s("archspace")),
            ("schema", Json::i(1)),
            ("workload", Json::s(workload.name())),
            ("archs", Json::arr(rows)),
        ]);
        std::fs::write(&out, format!("{}\n", doc.pretty()))
            .with_context(|| format!("writing {}", out.display()))?;
        eprintln!("arch: wrote {}", out.display());
        return Ok(());
    }

    println!("registered architectures (usable as SET in pim:*/pim-opt:*/pim-exec:* ids):");
    println!();
    for name in registered() {
        let def = archdef::def_named(name).expect("registered");
        println!("  {:<12} {}", name, describe(def));
        println!("  {:<12}   {}", "", def.provenance);
    }
    Ok(())
}

/// Attach the in-memory LRU tier (`--mem-cache N`, default 256 entries,
/// 0 disables) to the service's disk cache; no-op when `--no-cache`.
fn attach_mem_cache(service: EvalService, args: &Args) -> anyhow::Result<EvalService> {
    let mem = args.flag_usize("mem-cache", 256).map_err(anyhow::Error::msg)?;
    let cache = service.cache().cloned().map(|c| c.with_memory(mem));
    Ok(service.with_cache(cache))
}

/// Long-running JSONL daemon: stdin/stdout by default, a concurrent TCP
/// listener with `--listen HOST:PORT`.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let service = attach_mem_cache(service_from(args)?, args)?;
    let Some(listen) = args.flag_opt("listen") else {
        let stdin = std::io::stdin();
        let summary =
            convpim::service::serve(&service, stdin.lock(), std::io::stdout(), service.jobs())?;
        eprintln!(
            "serve: {} request(s) — {} ok, {} error(s), {} cache hit(s)",
            summary.requests, summary.ok, summary.errors, summary.cache_hits
        );
        return Ok(());
    };

    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding --listen {listen}"))?;
    let local = listener.local_addr().context("reading bound address")?;
    // TCP admission defaults to bounded (32 in-system evaluations per
    // worker) so an unattended daemon sheds instead of queueing without
    // limit; --queue 0 opts back into unbounded.
    let queue = match args.flag_opt("queue") {
        Some(_) => args.flag_usize("queue", 0).map_err(anyhow::Error::msg)?,
        None => 32 * resolve_jobs(service.jobs(), None),
    };
    // The first stderr line is machine-parsable so scripts (and the TCP
    // integration tests) can discover the port behind `--listen :0`.
    eprintln!("serve: listening on {local} (queue {queue})");

    // The TCP daemon ends the way the pipe daemon does: when stdin
    // closes. A watcher thread turns that EOF into stop + listener wake.
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    {
        let stop = std::sync::Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut stdin = std::io::stdin().lock();
            let mut buf = [0u8; 4096];
            while matches!(std::io::Read::read(&mut stdin, &mut buf), Ok(n) if n > 0) {}
            stop.store(true, std::sync::atomic::Ordering::SeqCst);
            convpim::service::wake_listener(local);
        });
    }

    let summary =
        convpim::service::serve_tcp(&service, listener, service.jobs(), queue, &stop)?;
    eprintln!(
        "serve: {} session(s) — {} request(s), {} ok, {} error(s), {} shed, {} cache hit(s)",
        summary.sessions,
        summary.totals.requests,
        summary.totals.ok,
        summary.totals.errors,
        summary.totals.shed,
        summary.totals.cache_hits
    );
    Ok(())
}

/// Deterministic load generator: measure a (self-hosted or `--addr`)
/// daemon at fixed concurrency levels and write `BENCH_serve.json`.
fn cmd_loadgen(args: &Args) -> anyhow::Result<()> {
    let clients_arg = args.flag("clients", "4,16");
    let levels: Vec<usize> = clients_arg
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse::<usize>().map_err(|_| {
                anyhow::Error::msg(format!(
                    "--clients must be a comma-separated list of counts, got `{s}`"
                ))
            })
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!levels.is_empty(), "--clients needs at least one level");
    let requests = args.flag_usize("requests", 256).map_err(anyhow::Error::msg)?;
    let seed = args.flag_usize("seed", 0xBEEF).map_err(anyhow::Error::msg)? as u64;
    let jobs = args.flag_usize("jobs", 0).map_err(anyhow::Error::msg)?;
    let queue = args.flag_usize("queue", 0).map_err(anyhow::Error::msg)?;
    let mem = args.flag_usize("mem-cache", 256).map_err(anyhow::Error::msg)?;
    let cache = if args.switch("no-cache") {
        None
    } else {
        Some(
            ResultCache::new(args.flag("cache-dir", service::DEFAULT_CACHE_DIR))
                .with_memory(mem),
        )
    };
    let cfg = convpim::service::LoadgenConfig {
        addr: args.flag_opt("addr").map(|s| s.to_string()),
        levels,
        requests,
        seed,
        jobs,
        queue,
        cache,
        out: Some(args.flag("out", "BENCH_serve.json").into()),
    };
    let doc = convpim::service::run_loadgen(&cfg)?;
    println!("{}", doc.pretty());
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let service = EvalService::new().with_cache(None);
    let resp = service.submit(&EvalRequest::Info);
    print!("{}", resp.stdout);
    match resp.meta.ok {
        true => Ok(()),
        false => Err(response_error(&resp)),
    }
}

fn cmd_list() -> anyhow::Result<()> {
    let service = EvalService::new().with_cache(None);
    print!("{}", service.submit(&EvalRequest::List).stdout);
    Ok(())
}
