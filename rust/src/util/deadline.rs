//! Cooperative evaluation deadlines.
//!
//! PR 6's `deadline_ms` only bounded *queue wait*: once a request was
//! admitted and evaluation began, it ran to completion no matter how far
//! past its deadline it was. Executed-network requests can run for
//! seconds, so the net executor ([`crate::pim::netexec`]) now takes a
//! [`Deadline`] and polls it **between tiles** — the natural preemption
//! point of crossbar execution (cheap: one `Instant::now()` per tile,
//! thousands of cycles of simulated work apart). An expired deadline
//! aborts the evaluation with an error whose message starts with
//! [`DEADLINE_EXPIRED`], which the serve layer maps to the same
//! structured `deadline` error class as a queue-wait expiry.

use std::time::{Duration, Instant};

use anyhow::Result;

/// Marker prefix of deadline-expiry errors; the serve layer classifies
/// evaluation errors whose message starts with this as `deadline` rather
/// than `eval` failures.
pub const DEADLINE_EXPIRED: &str = "deadline expired";

/// An optional wall-clock deadline, checked cooperatively.
#[derive(Clone, Copy, Debug, Default)]
pub struct Deadline {
    at: Option<Instant>,
}

impl Deadline {
    /// No deadline: every check passes.
    pub fn none() -> Deadline {
        Deadline { at: None }
    }

    /// Deadline at an absolute instant.
    pub fn at(at: Instant) -> Deadline {
        Deadline { at: Some(at) }
    }

    /// Deadline `ms` milliseconds from now.
    pub fn in_ms(ms: u64) -> Deadline {
        Deadline {
            at: Instant::now().checked_add(Duration::from_millis(ms)),
        }
    }

    /// `in_ms` when a budget is present, `none` otherwise — the shape the
    /// service layer's optional `deadline_ms` field arrives in.
    pub fn from_opt_ms(ms: Option<u64>) -> Deadline {
        ms.map_or_else(Deadline::none, Deadline::in_ms)
    }

    /// True when a deadline is set and has passed.
    pub fn expired(&self) -> bool {
        self.at.is_some_and(|t| Instant::now() >= t)
    }

    /// Error out (with the [`DEADLINE_EXPIRED`] marker) when expired.
    pub fn check(&self, during: &str) -> Result<()> {
        anyhow::ensure!(!self.expired(), "{DEADLINE_EXPIRED} during {during}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_expires() {
        let d = Deadline::none();
        assert!(!d.expired());
        d.check("anything").unwrap();
        assert!(!Deadline::from_opt_ms(None).expired());
    }

    #[test]
    fn past_deadline_expires_with_marker() {
        let d = Deadline::at(Instant::now() - Duration::from_millis(1));
        assert!(d.expired());
        let err = d.check("net evaluation").unwrap_err().to_string();
        assert!(err.starts_with(DEADLINE_EXPIRED), "{err}");
        assert!(err.contains("net evaluation"), "{err}");
    }

    #[test]
    fn future_deadline_passes() {
        let d = Deadline::in_ms(60_000);
        assert!(!d.expired());
        d.check("x").unwrap();
        assert!(Deadline::from_opt_ms(Some(60_000)).check("x").is_ok());
    }
}
