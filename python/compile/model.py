"""Layer-2 JAX compute graphs (build-time only; never on the request path).

Defines every computation the Rust coordinator executes through PJRT:

* three **micro-CNNs** carrying the architectural motifs of the paper's
  benchmarks (AlexNet-style dense conv stack, GoogLeNet-style inception
  block, ResNet-style residual bottlenecks) at 64×64×3 scale — the
  *measured* substrate that validates the Figure 6/7 model orderings on
  real executions (DESIGN.md §2 Substitutions);
* a **training step** (cross-entropy + SGD) for the Figure 7 measured
  series;
* **batched matmuls** at several n for the Figure 5 measured series;
* **element-wise add/mul** vectors for the Figure 3 measured series;
* **attention decode** (matrix-vector against a KV cache) for the §6
  discussion workload;
* the **PIM crossbar kernel** executing a vectored fixed-16 addition —
  the Layer-1 hot-spot exported through the same AOT path and
  cross-checked against the native Rust simulator.

All convolutions route through the Pallas matmul kernel
(`kernels.conv2d`), so the L1 kernel lowers into the same HLO the Rust
runtime loads.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import conv2d as k_conv
from .kernels import crossbar as k_xbar

# ---------------------------------------------------------------------------
# Parameter initialization (deterministic: the AOT path bakes shapes only,
# but tests and the e2e driver need real values).
# ---------------------------------------------------------------------------


def _conv_p(key, cout, cin, k):
    w = jax.random.normal(key, (cout, cin, k, k), jnp.float32)
    return w * jnp.sqrt(2.0 / (cin * k * k))


def _fc_p(key, nin, nout):
    w = jax.random.normal(key, (nin, nout), jnp.float32) * jnp.sqrt(2.0 / nin)
    return w


class MicroCnnParams(NamedTuple):
    """Parameters of the AlexNet-motif micro CNN."""

    c1: jnp.ndarray
    c2: jnp.ndarray
    c3: jnp.ndarray
    fc1: jnp.ndarray
    fc2: jnp.ndarray


def micro_alexnet_init(key) -> MicroCnnParams:
    ks = jax.random.split(key, 5)
    return MicroCnnParams(
        c1=_conv_p(ks[0], 32, 3, 5),
        c2=_conv_p(ks[1], 64, 32, 3),
        c3=_conv_p(ks[2], 64, 64, 3),
        fc1=_fc_p(ks[3], 64 * 8 * 8, 256),
        fc2=_fc_p(ks[4], 256, 10),
    )


def _pool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def micro_alexnet_fwd(params: MicroCnnParams, x: jnp.ndarray) -> jnp.ndarray:
    """Dense conv stack (high reuse — the AlexNet motif). x: (N,3,64,64)."""
    h = jax.nn.relu(k_conv.conv2d(x, params.c1, stride=1, padding=2))
    h = _pool2(h)  # 32x32
    h = jax.nn.relu(k_conv.conv2d(h, params.c2, stride=1, padding=1))
    h = _pool2(h)  # 16x16
    h = jax.nn.relu(k_conv.conv2d(h, params.c3, stride=1, padding=1))
    h = _pool2(h)  # 8x8
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(k_conv.matmul(h, params.fc1))
    return k_conv.matmul(h, params.fc2)


class MicroResNetParams(NamedTuple):
    stem: jnp.ndarray
    b1a: jnp.ndarray
    b1b: jnp.ndarray
    b2a: jnp.ndarray
    b2b: jnp.ndarray
    down2: jnp.ndarray
    fc: jnp.ndarray


def micro_resnet_init(key) -> MicroResNetParams:
    ks = jax.random.split(key, 7)
    return MicroResNetParams(
        stem=_conv_p(ks[0], 32, 3, 3),
        b1a=_conv_p(ks[1], 32, 32, 3),
        b1b=_conv_p(ks[2], 32, 32, 3),
        b2a=_conv_p(ks[3], 64, 32, 3),
        b2b=_conv_p(ks[4], 64, 64, 3),
        down2=_conv_p(ks[5], 64, 32, 1),
        fc=_fc_p(ks[6], 64, 10),
    )


def micro_resnet_fwd(params: MicroResNetParams, x: jnp.ndarray) -> jnp.ndarray:
    """Residual blocks with 1×1 projection (low-reuse residual adds —
    the ResNet motif the paper blames for the larger exp/theo gap)."""
    h = jax.nn.relu(k_conv.conv2d(x, params.stem, stride=2, padding=1))  # 32
    # Block 1 (identity skip).
    r = h
    h = jax.nn.relu(k_conv.conv2d(h, params.b1a, stride=1, padding=1))
    h = k_conv.conv2d(h, params.b1b, stride=1, padding=1)
    h = jax.nn.relu(h + r)
    # Block 2 (strided, projected skip).
    r = k_conv.conv2d(h, params.down2, stride=2, padding=0)
    h = jax.nn.relu(k_conv.conv2d(h, params.b2a, stride=2, padding=1))
    h = k_conv.conv2d(h, params.b2b, stride=1, padding=1)
    h = jax.nn.relu(h + r)  # (N,64,16,16)
    h = jnp.mean(h, axis=(2, 3))
    return k_conv.matmul(h, params.fc)


class MicroInceptionParams(NamedTuple):
    stem: jnp.ndarray
    b1: jnp.ndarray
    b2r: jnp.ndarray
    b2: jnp.ndarray
    b3r: jnp.ndarray
    b3: jnp.ndarray
    fc: jnp.ndarray


def micro_googlenet_init(key) -> MicroInceptionParams:
    ks = jax.random.split(key, 7)
    return MicroInceptionParams(
        stem=_conv_p(ks[0], 32, 3, 3),
        b1=_conv_p(ks[1], 16, 32, 1),
        b2r=_conv_p(ks[2], 16, 32, 1),
        b2=_conv_p(ks[3], 32, 16, 3),
        b3r=_conv_p(ks[4], 8, 32, 1),
        b3=_conv_p(ks[5], 16, 8, 5),
        fc=_fc_p(ks[6], 64, 10),
    )


def micro_googlenet_fwd(params: MicroInceptionParams, x: jnp.ndarray) -> jnp.ndarray:
    """One inception module (parallel 1×1 / 3×3 / 5×5 branches with
    concat — the GoogLeNet motif: many small low-reuse 1×1 convs)."""
    h = jax.nn.relu(k_conv.conv2d(x, params.stem, stride=2, padding=1))  # 32
    h = _pool2(h)  # 16
    b1 = jax.nn.relu(k_conv.conv2d(h, params.b1, padding=0))
    b2 = jax.nn.relu(k_conv.conv2d(h, params.b2r, padding=0))
    b2 = jax.nn.relu(k_conv.conv2d(b2, params.b2, padding=1))
    b3 = jax.nn.relu(k_conv.conv2d(h, params.b3r, padding=0))
    b3 = jax.nn.relu(k_conv.conv2d(b3, params.b3, padding=2))
    h = jnp.concatenate([b1, b2, b3], axis=1)  # 64 ch
    h = jnp.mean(h, axis=(2, 3))
    return k_conv.matmul(h, params.fc)


MICRO_MODELS = {
    "alexnet": (micro_alexnet_init, micro_alexnet_fwd),
    "googlenet": (micro_googlenet_init, micro_googlenet_fwd),
    "resnet": (micro_resnet_init, micro_resnet_fwd),
}

# ---------------------------------------------------------------------------
# Training step (Figure 7 measured series).
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_train_step(fwd, lr: float = 0.01):
    """SGD train step over any micro model; donated params for in-place
    update in the lowered executable."""

    def loss_fn(params, x, y):
        return cross_entropy(fwd(params, x), y)

    def step(params, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new_params, loss

    return step


# ---------------------------------------------------------------------------
# Figure 3/5 measured substrates and the §6 decode workload.
# ---------------------------------------------------------------------------


def elementwise_add(u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    return u + v


def elementwise_mul(u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    return u * v


def batched_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(B, n, n) × (B, n, n) through XLA's native batched dot."""
    return jnp.einsum("bij,bjk->bik", a, b)


def attention_decode(q: jnp.ndarray, keys: jnp.ndarray, values: jnp.ndarray) -> jnp.ndarray:
    """Single-token decode attention: q (H, d), KV cache (H, S, d)."""
    scores = jnp.einsum("hd,hsd->hs", q, keys) / jnp.sqrt(q.shape[-1] * 1.0)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hs,hsd->hd", probs, values)


# ---------------------------------------------------------------------------
# Layer-1 crossbar kernel entry point (AOT-exported).
# ---------------------------------------------------------------------------

PIM_ADD_BITS = 16
PIM_ADD_ROWS = 256  # 8 uint32 words


def pim_fixed_add16(state: jnp.ndarray) -> jnp.ndarray:
    """Execute the 16-bit vectored PIM addition program on a packed
    crossbar state (uint32 (8, width))."""
    prog = k_xbar.assemble_fixed_add(PIM_ADD_BITS)
    run = k_xbar.make_crossbar_kernel(prog, interpret=True)
    return run(state)


def pim_add16_width() -> int:
    return k_xbar.program_width(k_xbar.assemble_fixed_add(PIM_ADD_BITS))


# ---------------------------------------------------------------------------
# AOT entry-point registry: name -> (jittable fn, example args).
# ---------------------------------------------------------------------------


def entry_points():
    """Every computation exported to artifacts/ by aot.py."""
    key = jax.random.PRNGKey(0)
    entries = {}

    # Micro CNN forward passes (batch 8).
    for name, (init, fwd) in MICRO_MODELS.items():
        params = init(key)
        x = jax.ShapeDtypeStruct((8, 3, 64, 64), jnp.float32)
        p_spec = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params
        )
        entries[f"cnn_{name}_fwd"] = (
            functools.partial(_fwd_tuple, fwd),
            (p_spec, x),
        )

    # Training step for the AlexNet-motif model.
    init, fwd = MICRO_MODELS["alexnet"]
    params = init(key)
    p_spec = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params
    )
    x = jax.ShapeDtypeStruct((8, 3, 64, 64), jnp.float32)
    y = jax.ShapeDtypeStruct((8,), jnp.int32)
    step = make_train_step(fwd)
    entries["cnn_alexnet_train_step"] = (_train_tuple(step), (p_spec, x, y))

    # Element-wise vectors (2^22 elements ≈ 16 MB per operand).
    vec = jax.ShapeDtypeStruct((1 << 22,), jnp.float32)
    entries["elementwise_add_f32"] = (lambda u, v: (elementwise_add(u, v),), (vec, vec))
    entries["elementwise_mul_f32"] = (lambda u, v: (elementwise_mul(u, v),), (vec, vec))

    # Batched matmuls for Figure 5 (batch shrinks as n grows: const FLOPs).
    for n, batch in [(16, 512), (32, 256), (64, 64), (128, 16), (256, 4)]:
        m = jax.ShapeDtypeStruct((batch, n, n), jnp.float32)
        entries[f"matmul_n{n}"] = (lambda a, b: (batched_matmul(a, b),), (m, m))

    # Attention decode (16 heads × 64 dim, 2048-token cache).
    q = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    kv = jax.ShapeDtypeStruct((16, 2048, 64), jnp.float32)
    entries["attention_decode"] = (
        lambda q2, k2, v2: (attention_decode(q2, k2, v2),),
        (q, kv, kv),
    )

    # The PIM crossbar kernel itself.
    st = jax.ShapeDtypeStruct((PIM_ADD_ROWS // 32, pim_add16_width()), jnp.uint32)
    entries["pim_fixed_add16"] = (lambda s: (pim_fixed_add16(s),), (st,))

    return entries


def _fwd_tuple(fwd, params, x):
    return (fwd(params, x),)


def _train_tuple(step):
    def f(params, x, y):
        new_params, loss = step(params, x, y)
        return tuple(jax.tree_util.tree_leaves(new_params)) + (loss,)

    return f
