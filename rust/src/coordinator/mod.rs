//! Experiment coordinator: the registry and runner that regenerate every
//! table and figure of the paper.
//!
//! Each experiment (one per paper artifact, see DESIGN.md §4) combines
//! three kinds of numbers:
//!
//! * **paper-scale analytic** — the PIM architecture model
//!   ([`crate::pim::arch`]) and GPU rooflines ([`crate::gpumodel`]) at
//!   Table 1 parameters; these are the figures the paper plots;
//! * **measured (testbed)** — real executions of the AOT artifacts
//!   through the PJRT runtime on this machine's CPU backend; these
//!   validate *relative* behaviour (orderings, gap shapes) and are
//!   labelled as testbed numbers, never mixed with paper-scale ones;
//! * **bit-exact validation** — crossbar-simulator runs that gate the
//!   analytic cycle counts behind real executions of the same microcode.
//!
//! The runner renders results as aligned text (console), markdown, CSV
//! and JSON under `results/`.

pub mod experiments;
pub mod report;

use anyhow::Result;

use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::table::Table;

/// Shared context for experiment execution.
pub struct Ctx {
    /// PJRT engine when artifacts are available (measured series);
    /// `None` runs the analytic/validation parts only.
    pub engine: Option<Engine>,
    /// Reduce measured iteration counts (CI mode).
    pub fast: bool,
    /// Random seed for synthesized measured inputs.
    pub seed: u64,
}

impl Ctx {
    /// Build a context, attaching the engine if artifacts exist.
    pub fn new(fast: bool) -> Ctx {
        Ctx::with_engine(
            match Engine::new() {
                Ok(e) => Some(e),
                Err(err) => {
                    eprintln!("note: measured series disabled ({err:#})");
                    None
                }
            },
            fast,
        )
    }

    /// Like [`Ctx::new`], but prints the measured-series availability note
    /// at most once per process — used by the parallel runner, where every
    /// worker builds its own context and the per-context note of
    /// [`Ctx::new`] would repeat for each experiment.
    pub fn new_quiet(fast: bool) -> Ctx {
        let engine = match Engine::new() {
            Ok(e) => Some(e),
            Err(err) => {
                static NOTE: std::sync::Once = std::sync::Once::new();
                NOTE.call_once(|| eprintln!("note: measured series disabled ({err:#})"));
                None
            }
        };
        Ctx::with_engine(engine, fast)
    }

    /// The single construction point both public constructors share.
    fn with_engine(engine: Option<Engine>, fast: bool) -> Ctx {
        Ctx {
            engine,
            fast,
            seed: 0xC0FFEE,
        }
    }

    /// Analytic-only context (no artifacts needed).
    pub fn analytic() -> Ctx {
        Ctx {
            engine: None,
            fast: true,
            seed: 0xC0FFEE,
        }
    }

    /// Measured iterations for a timed run.
    pub fn iters(&self) -> usize {
        if self.fast {
            2
        } else {
            5
        }
    }
}

/// One table within an experiment result.
#[derive(Clone)]
pub struct Section {
    pub caption: String,
    pub table: Table,
}

/// The output of one experiment.
pub struct ExperimentResult {
    /// Registry id (`fig3`, `table1`, `sens-gpu`, …).
    pub id: String,
    /// Human title (matches the paper artifact).
    pub title: String,
    pub sections: Vec<Section>,
    /// Free-form observations (shape checks, paper-delta notes).
    pub notes: Vec<String>,
    /// Machine-readable payload for results/<id>.json.
    pub json: Json,
}

impl ExperimentResult {
    /// Render for the console.
    pub fn text(&self) -> String {
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        for s in &self.sections {
            out.push_str(&format!("{}\n{}\n", s.caption, s.table.text()));
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Render as markdown.
    pub fn markdown(&self) -> String {
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        for s in &self.sections {
            out.push_str(&format!("**{}**\n\n{}\n", s.caption, s.table.markdown()));
        }
        if !self.notes.is_empty() {
            out.push_str("Notes:\n");
            for n in &self.notes {
                out.push_str(&format!("- {n}\n"));
            }
            out.push('\n');
        }
        out
    }
}

/// All experiment ids, in paper order (the trailing `conv-exec` is the
/// executed-convolution cross-validation added on top of the paper set).
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "table1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "sens-gpu", "sens-fp16",
        "sens-dims", "conv-exec",
    ]
}

/// Run a batch of experiments concurrently on a thread pool.
///
/// Each experiment gets a fresh context from `mk_ctx` (contexts are not
/// shared across threads; the measured-series engine, when present, is
/// per-worker state, so pjrt builds pay engine startup once per experiment
/// here — prefer a serial run for measured series). Results come back in
/// input order, one per id, so reporting stays deterministic regardless of
/// scheduling. Experiments are independent by construction — they only
/// read the static models — and the bit-exact validation layers underneath
/// are themselves bit-identical across thread counts (see
/// [`crate::pim::xbar`]), so the analytic report content of a concurrent
/// run is byte-identical to a serial one. Wall-clock *measured* numbers
/// (pjrt builds) are the exception: concurrent execution contends for
/// cores and skews timings.
pub fn run_many(
    ids: &[String],
    mk_ctx: &(dyn Fn() -> Ctx + Sync),
    pool: &crate::util::pool::Pool,
) -> Vec<Result<ExperimentResult>> {
    let mut slots: Vec<Option<Result<ExperimentResult>>> = ids.iter().map(|_| None).collect();
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = slots
        .iter_mut()
        .zip(ids)
        .map(|(slot, id)| {
            Box::new(move || {
                let mut ctx = mk_ctx();
                *slot = Some(run_experiment(id, &mut ctx));
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.run(tasks);
    slots
        .into_iter()
        .map(|slot| slot.expect("pool.run completed every task"))
        .collect()
}

/// Run one experiment by id.
pub fn run_experiment(id: &str, ctx: &mut Ctx) -> Result<ExperimentResult> {
    match id {
        "table1" => experiments::table1(ctx),
        "fig3" => experiments::fig3(ctx),
        "fig4" => experiments::fig4(ctx),
        "fig5" => experiments::fig5(ctx),
        "fig6" => experiments::fig6(ctx),
        "fig7" => experiments::fig7(ctx),
        "fig8" => experiments::fig8(ctx),
        "sens-gpu" => experiments::sens_gpu(ctx),
        "sens-fp16" => experiments::sens_fp16(ctx),
        "sens-dims" => experiments::sens_dims(ctx),
        "conv-exec" => experiments::conv_exec(ctx),
        other => anyhow::bail!(
            "unknown experiment `{other}`; available: {}",
            all_ids().join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_id_runs_analytically() {
        let mut ctx = Ctx::analytic();
        for id in all_ids() {
            let r = run_experiment(id, &mut ctx).unwrap_or_else(|e| panic!("{id}: {e:#}"));
            assert!(!r.sections.is_empty(), "{id} produced no tables");
            assert!(!r.text().is_empty());
            assert!(!r.markdown().is_empty());
        }
    }

    #[test]
    fn unknown_id_errors() {
        let mut ctx = Ctx::analytic();
        assert!(run_experiment("fig99", &mut ctx).is_err());
    }

    #[test]
    fn run_many_failure_preserves_completed_results() {
        // Regression for the PR 1 fix: a failing experiment in a parallel
        // batch must yield an Err in *its own slot* while every other
        // experiment's completed result is still returned, in input order.
        let ids: Vec<String> = vec!["fig4".into(), "fig99-injected".into(), "table1".into()];
        let pool = crate::util::pool::Pool::new(2);
        let results = run_many(&ids, &Ctx::analytic, &pool);
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].as_ref().unwrap().id, "fig4");
        let err = results[1].as_ref().err().expect("unknown id must fail");
        assert!(format!("{err:#}").contains("fig99-injected"));
        assert_eq!(results[2].as_ref().unwrap().id, "table1");
    }

    #[test]
    fn run_many_is_ordered_and_deterministic() {
        let ids: Vec<String> = all_ids().iter().map(|s| s.to_string()).collect();
        let pool = crate::util::pool::Pool::new(4);
        let results = run_many(&ids, &Ctx::analytic, &pool);
        assert_eq!(results.len(), ids.len());
        for (id, r) in ids.iter().zip(&results) {
            let r = r.as_ref().unwrap_or_else(|e| panic!("{id}: {e:#}"));
            assert_eq!(&r.id, id, "results must come back in input order");
        }
        // A concurrent run renders byte-identically to a serial rerun.
        let mut ctx = Ctx::analytic();
        let serial = run_experiment("fig4", &mut ctx).unwrap();
        let idx = ids.iter().position(|i| i == "fig4").unwrap();
        let parallel = results[idx].as_ref().unwrap();
        assert_eq!(serial.markdown(), parallel.markdown());
    }
}
