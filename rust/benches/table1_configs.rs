//! Table 1 regeneration bench: prints the configuration tables and times
//! the microcode compilers (program generation is part of the toolchain's
//! cost envelope).

use convpim::coordinator::{run_experiment, Ctx};
use convpim::pim::fixed::{self, FixedOp};
use convpim::pim::float;
use convpim::pim::gates::GateSet;
use convpim::pim::softfloat::Format;
use convpim::util::bench::{bench, header, report, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    header("table1: configurations");
    let mut ctx = Ctx::analytic();
    let r = run_experiment("table1", &mut ctx).unwrap();
    println!("{}", r.text());

    header("microcode compiler throughput (programs/s)");
    report(bench("compile fixed32 add", 1.0, &cfg, || {
        let _ = fixed::program(FixedOp::Add, 32, GateSet::MemristiveNor);
    }));
    report(bench("compile fixed32 mul", 1.0, &cfg, || {
        let _ = fixed::program(FixedOp::Mul, 32, GateSet::MemristiveNor);
    }));
    report(bench("compile fp32 add", 1.0, &cfg, || {
        let _ = float::program(FixedOp::Add, Format::FP32, GateSet::MemristiveNor);
    }));
    report(bench("compile fp64 div", 1.0, &cfg, || {
        let _ = float::program(FixedOp::Div, Format::FP64, GateSet::MemristiveNor);
    }));
}
