//! Builtin architecture definitions.
//!
//! The same declarative catalogue lime's `define_generic_architecture!`
//! ships (Ambit, SIMDRAM, IMPLY, PLiM, FELIX), expressed as [`ArchDef`]
//! data against this repo's cost model. Two kinds of entries:
//!
//! * `memristive` / `dram` describe the paper's Table-1 technologies —
//!   [`crate::archdef::lookup`] resolves these names to the legacy
//!   [`crate::pim::gates::GateSet`] variants, so the defs exist for
//!   `convpim arch` describe/validate output only;
//! * `nor` / `simdram` are their *twins on the ArchDef path*: identical
//!   numbers evaluated through [`crate::pim::gates::GateSet::Arch`],
//!   which is what lets `tests/archdef_diff.rs` prove the DSL cost- and
//!   bit-identical to the hard-coded paths (and gives CI's 3-way
//!   compare its `pim:nor`/`pim:simdram` legs).
//!
//! Cycle costs follow the repo's macro-sequence discipline (the legacy
//! memristive `copy = 4` means "two NOTs"): each opcode's cost is the
//! length of the native micro-sequence realizing it, so serial families
//! like IMPLY price NOR higher without changing program *shape*.

use super::ArchDef;
use crate::pim::gates::{GateCosts, LogicFamily, ILLEGAL_COST};

fn nor_costs(nor2: u64, nor3: u64, not: u64, copy: u64, set: u64, energy_j: f64) -> GateCosts {
    GateCosts {
        nor2,
        nor3,
        not,
        maj3: ILLEGAL_COST,
        copy,
        set,
        gate_energy_j: energy_j,
        move_energy_j: energy_j,
    }
}

fn maj_costs(maj3: u64, not: u64, copy: u64, set: u64, energy_j: f64) -> GateCosts {
    GateCosts {
        nor2: ILLEGAL_COST,
        nor3: ILLEGAL_COST,
        not,
        maj3,
        copy,
        set,
        gate_energy_j: energy_j,
        move_energy_j: energy_j,
    }
}

/// All builtin definitions, in report order.
pub(super) fn all() -> Vec<ArchDef> {
    vec![
        ArchDef {
            name: "memristive".into(),
            display: "Memristive PIM".into(),
            family: LogicFamily::Nor,
            rows: 1024,
            cols: 1024,
            clock_hz: 333e6,
            costs: nor_costs(2, 2, 2, 4, 1, 6.4e-15),
            max_power_w: Some(860.0),
            provenance: "ConvPIM Table 1 (MAGIC stateful logic). Describes the legacy \
                         hard-coded path; `nor` is the ArchDef-path twin."
                .into(),
        },
        ArchDef {
            name: "nor".into(),
            display: "Memristive PIM (archdef)".into(),
            family: LogicFamily::Nor,
            rows: 1024,
            cols: 1024,
            clock_hz: 333e6,
            costs: nor_costs(2, 2, 2, 4, 1, 6.4e-15),
            max_power_w: Some(860.0),
            provenance: "Twin of `memristive` evaluated through the ArchDef path; proven \
                         cost- and bit-identical in tests/archdef_diff.rs."
                .into(),
        },
        ArchDef {
            name: "dram".into(),
            display: "DRAM PIM".into(),
            family: LogicFamily::Maj,
            rows: 65536,
            cols: 1024,
            clock_hz: 0.5e6,
            costs: maj_costs(4, 3, 2, 1, 391e-15),
            max_power_w: Some(80.0),
            provenance: "ConvPIM Table 1 (SIMDRAM-style TRA majority). Describes the legacy \
                         hard-coded path; `simdram` is the ArchDef-path twin."
                .into(),
        },
        ArchDef {
            name: "simdram".into(),
            display: "SIMDRAM PIM (archdef)".into(),
            family: LogicFamily::Maj,
            rows: 65536,
            cols: 1024,
            clock_hz: 0.5e6,
            costs: maj_costs(4, 3, 2, 1, 391e-15),
            max_power_w: Some(80.0),
            provenance: "Twin of `dram` evaluated through the ArchDef path (SIMDRAM, \
                         Hajinazar et al. ASPLOS'21); proven cost- and bit-identical in \
                         tests/archdef_diff.rs."
                .into(),
        },
        ArchDef {
            name: "ambit".into(),
            display: "Ambit DRAM PIM".into(),
            family: LogicFamily::Maj,
            rows: 65536,
            cols: 1024,
            clock_hz: 0.5e6,
            costs: maj_costs(7, 4, 2, 1, 391e-15),
            max_power_w: Some(80.0),
            provenance: "Ambit (Seshadri et al. MICRO'17): no compute-row mapping tricks, so \
                         MAJ = 3 operand AAP copies (2 cycles each) + the triple-row \
                         activation = 7, NOT = AAP into the DCC row + AAP back = 4; same \
                         DRAM array geometry/energy as Table 1."
                .into(),
        },
        ArchDef {
            name: "imply".into(),
            display: "IMPLY memristive PIM".into(),
            family: LogicFamily::Nor,
            rows: 1024,
            cols: 1024,
            clock_hz: 200e6,
            costs: nor_costs(6, 8, 2, 4, 1, 8.2e-15),
            max_power_w: None,
            provenance: "Material implication (Borghetti et al. 2010; Lehtonen & Laiho): \
                         NOR2 = init + 2 serial IMPLY steps + result restore ≈ 6 cycles, \
                         each extra input +2; slower serial stepping (200 MHz) and higher \
                         per-op energy than MAGIC. Power derived at max parallelism."
                .into(),
        },
        ArchDef {
            name: "plim".into(),
            display: "PLiM RM3 PIM".into(),
            family: LogicFamily::Maj,
            rows: 1024,
            cols: 1024,
            clock_hz: 100e6,
            costs: maj_costs(3, 2, 2, 1, 10e-15),
            max_power_w: None,
            provenance: "PLiM computer (Gaillardon et al. DATE'16): native resistive \
                         majority (RM3) = 3 sequential bitline ops, NOT = 2 via RM3 with \
                         constants, on memristive crossbar geometry. Power derived at max \
                         parallelism."
                .into(),
        },
        ArchDef {
            name: "felix".into(),
            display: "FELIX PIM".into(),
            family: LogicFamily::Nor,
            rows: 1024,
            cols: 1024,
            clock_hz: 333e6,
            costs: nor_costs(1, 2, 1, 2, 1, 4.7e-15),
            max_power_w: None,
            provenance: "FELIX (Gupta et al. ICCAD'18): single-cycle NOR/NOT via \
                         simultaneous initialization+execution voltages, 2-cycle NOR3 and \
                         copy, lower per-gate energy. Power derived at max parallelism."
                .into(),
        },
    ]
}
