//! The typed response side of the evaluation service: [`EvalResponse`].
//!
//! Every request kind produces the same structured envelope: rendered
//! tables with captions, free-form notes, a machine-readable JSON
//! payload, the exact CLI stdout bytes, and execution metadata (ok flag,
//! error text, cache disposition, per-campaign hit/computed counts,
//! elapsed wall-clock). The CLI adapters print
//! [`EvalResponse::stdout`] verbatim — that is what makes the redesigned
//! subcommands byte-identical to the pre-service ones — while `convpim
//! serve` ships [`EvalResponse::to_json`] as one JSONL line.
//!
//! Responses of deterministic requests round-trip through the result
//! cache: [`EvalResponse::to_cache_json`] strips the per-invocation
//! metadata, [`EvalResponse::from_cache_json`] restores the response with
//! fresh metadata, and because every content field is either a string or
//! goes through the shortest-round-trip float formatting of
//! [`Json`], a cache-served response renders byte-identically to the
//! computed one.

use crate::coordinator::{ExperimentResult, Section};
use crate::util::json::Json;
use crate::util::table::Table;

/// Where a response came from, cache-wise.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheStatus {
    /// Served from the content-addressed result cache.
    Hit,
    /// Evaluated this invocation (and stored, when a cache is attached).
    Computed,
    /// A cacheable request, but the service runs without a cache.
    Disabled,
    /// This request kind is never response-cached (campaigns cache per
    /// point; `info`/`list` are machine-dependent/trivial).
    Uncacheable,
}

impl CacheStatus {
    /// Wire name.
    pub fn name(self) -> &'static str {
        match self {
            CacheStatus::Hit => "hit",
            CacheStatus::Computed => "computed",
            CacheStatus::Disabled => "disabled",
            CacheStatus::Uncacheable => "uncacheable",
        }
    }
}

/// Execution metadata attached to every response.
#[derive(Clone, Debug)]
pub struct EvalMeta {
    /// The request evaluated successfully (all cells passed, no errors).
    pub ok: bool,
    /// Error text (`{e:#}`-formatted context chain) when `ok` is false.
    pub error: Option<String>,
    /// Cache disposition of this response.
    pub cache: CacheStatus,
    /// Campaign-level cache hits (campaign responses; 0 otherwise).
    pub hits: usize,
    /// Campaign-level computed points (campaign responses; 0 otherwise).
    pub computed: usize,
    /// Wall-clock milliseconds spent serving the request.
    pub elapsed_ms: f64,
}

impl EvalMeta {
    /// Metadata for a freshly computed, successful response.
    pub fn computed() -> EvalMeta {
        EvalMeta {
            ok: true,
            error: None,
            cache: CacheStatus::Computed,
            hits: 0,
            computed: 0,
            elapsed_ms: 0.0,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(self.ok)),
            (
                "error",
                self.error
                    .as_ref()
                    .map(|e| Json::s(e.clone()))
                    .unwrap_or(Json::Null),
            ),
            ("cache", Json::s(self.cache.name())),
            ("hits", Json::i(self.hits as i64)),
            ("computed", Json::i(self.computed as i64)),
            ("elapsed_ms", Json::n(self.elapsed_ms)),
        ])
    }
}

/// The structured result of one [`EvalRequest`] evaluation.
///
/// [`EvalRequest`]: crate::service::EvalRequest
#[derive(Clone, Debug)]
pub struct EvalResponse {
    /// Echo of the request kind (`experiment`, `campaign`, …; `error`
    /// for unparsable serve lines).
    pub kind: String,
    /// Primary identifier: experiment id, campaign name, layer selector.
    pub id: String,
    /// Human title.
    pub title: String,
    /// Exact CLI stdout bytes for this response (print with `print!`).
    pub stdout: String,
    /// Rendered tables ([`Section`]: caption + table; captions may
    /// be empty for single-table responses).
    pub sections: Vec<Section>,
    /// Free-form observations.
    pub notes: Vec<String>,
    /// Machine-readable payload (experiment JSON, campaign rows, …).
    pub payload: Json,
    /// Execution metadata (never cached; always per-invocation).
    pub meta: EvalMeta,
}

impl EvalResponse {
    /// A failed response carrying only an error.
    pub fn error(kind: impl Into<String>, id: impl Into<String>, error: String) -> EvalResponse {
        EvalResponse {
            kind: kind.into(),
            id: id.into(),
            title: String::new(),
            stdout: String::new(),
            sections: Vec::new(),
            notes: Vec::new(),
            payload: Json::Null,
            meta: EvalMeta {
                ok: false,
                error: Some(error),
                cache: CacheStatus::Uncacheable,
                hits: 0,
                computed: 0,
                elapsed_ms: 0.0,
            },
        }
    }

    /// Wrap a registry [`ExperimentResult`]: sections, notes and payload
    /// are carried over and `stdout` is the exact `convpim run`
    /// rendering (`ExperimentResult::text()` plus the trailing newline
    /// `println!` appends).
    pub fn from_experiment(r: &ExperimentResult) -> EvalResponse {
        EvalResponse {
            kind: "experiment".into(),
            id: r.id.clone(),
            title: r.title.clone(),
            stdout: format!("{}\n", r.text()),
            sections: r.sections.clone(),
            notes: r.notes.clone(),
            payload: r.json.clone(),
            meta: EvalMeta::computed(),
        }
    }

    /// Reconstruct the registry-shaped result (for `results/` report
    /// writing). Only meaningful for `experiment` responses; other kinds
    /// return `None`.
    pub fn to_experiment_result(&self) -> Option<ExperimentResult> {
        if self.kind != "experiment" {
            return None;
        }
        Some(ExperimentResult {
            id: self.id.clone(),
            title: self.title.clone(),
            sections: self.sections.clone(),
            notes: self.notes.clone(),
            json: self.payload.clone(),
        })
    }

    /// Full wire form (one `convpim serve` response line, minus the
    /// `seq` the daemon adds).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::s(self.kind.clone())),
            ("id", Json::s(self.id.clone())),
            ("title", Json::s(self.title.clone())),
            ("stdout", Json::s(self.stdout.clone())),
            (
                "sections",
                Json::arr(
                    self.sections
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("caption", Json::s(s.caption.clone())),
                                ("table", s.table.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "notes",
                Json::arr(self.notes.iter().map(|n| Json::s(n.clone())).collect()),
            ),
            ("payload", self.payload.clone()),
            ("meta", self.meta.to_json()),
        ])
    }

    /// The cacheable subset: everything except `meta` (which is
    /// per-invocation by definition).
    pub fn to_cache_json(&self) -> Json {
        let mut doc = self.to_json();
        if let Json::Obj(m) = &mut doc {
            m.remove("meta");
        }
        doc
    }

    /// Restore a response from a cache entry written by
    /// [`EvalResponse::to_cache_json`], attaching fresh metadata. Returns
    /// `None` on missing/mistyped fields (a stale entry layout degrades
    /// to recompute).
    pub fn from_cache_json(doc: &Json, meta: EvalMeta) -> Option<EvalResponse> {
        let s = |key: &str| Some(doc.get(key)?.as_str()?.to_string());
        let sections = doc
            .get("sections")?
            .as_arr()?
            .iter()
            .map(|j| {
                Some(Section {
                    caption: j.get("caption")?.as_str()?.to_string(),
                    table: Table::from_json(j.get("table")?)?,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        let notes = doc
            .get("notes")?
            .as_arr()?
            .iter()
            .map(|n| n.as_str().map(str::to_string))
            .collect::<Option<Vec<_>>>()?;
        Some(EvalResponse {
            kind: s("kind")?,
            id: s("id")?,
            title: s("title")?,
            stdout: s("stdout")?,
            sections,
            notes,
            payload: doc.get("payload")?.clone(),
            meta,
        })
    }
}

/// Shorthand used by the service handlers: format an error the way the
/// CLI reports anyhow chains (`{e:#}`).
pub fn error_text(e: &anyhow::Error) -> String {
    format!("{e:#}")
}

/// Build an error [`EvalResponse`] from an anyhow error.
pub fn error_response(
    kind: impl Into<String>,
    id: impl Into<String>,
    e: &anyhow::Error,
) -> EvalResponse {
    EvalResponse::error(kind, id, error_text(e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_experiment, Ctx};

    #[test]
    fn experiment_response_round_trips_through_cache_json() {
        let mut ctx = Ctx::analytic();
        let r = run_experiment("table1", &mut ctx).unwrap();
        let resp = EvalResponse::from_experiment(&r);
        assert_eq!(resp.stdout, format!("{}\n", r.text()));

        let entry = resp.to_cache_json();
        assert!(entry.get("meta").is_none(), "meta must not be cached");
        let back = EvalResponse::from_cache_json(
            &Json::parse(&entry.compact()).unwrap(),
            EvalMeta::computed(),
        )
        .unwrap();
        assert_eq!(back.stdout, resp.stdout, "cache round trip must be exact");
        assert_eq!(back.payload, resp.payload);
        assert_eq!(back.notes, resp.notes);
        assert_eq!(back.sections.len(), resp.sections.len());
        for (a, b) in back.sections.iter().zip(&resp.sections) {
            assert_eq!(a.caption, b.caption);
            assert_eq!(a.table, b.table);
        }

        // The reconstructed registry result renders identically too.
        let rebuilt = back.to_experiment_result().unwrap();
        assert_eq!(rebuilt.text(), r.text());
        assert_eq!(rebuilt.markdown(), r.markdown());
    }

    #[test]
    fn error_response_shape() {
        let resp = EvalResponse::error("experiment", "fig99", "no such figure".into());
        assert!(!resp.meta.ok);
        assert_eq!(resp.meta.error.as_deref(), Some("no such figure"));
        let wire = resp.to_json();
        assert_eq!(
            wire.get("meta").unwrap().get("ok").unwrap().as_bool(),
            Some(false)
        );
        assert_eq!(
            wire.get("meta").unwrap().get("cache").unwrap().as_str(),
            Some("uncacheable")
        );
    }

    #[test]
    fn stale_cache_layout_degrades_to_none() {
        assert!(EvalResponse::from_cache_json(
            &Json::parse(r#"{"kind": "experiment"}"#).unwrap(),
            EvalMeta::computed()
        )
        .is_none());
    }
}
