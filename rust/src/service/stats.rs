//! Serve-daemon observability: lock-free counters, gauges and a
//! fixed-bucket latency histogram behind the `stats` request kind.
//!
//! Everything here is plain `std::sync::atomic` — the daemon updates
//! counters from N session readers and M pool workers concurrently, and
//! a `{"kind": "stats"}` request snapshots them without stopping the
//! world. The snapshot is therefore *approximate across fields* (each
//! field is individually exact, but the set is not read under one lock);
//! that is the standard contract for production metrics endpoints and is
//! documented on the wire schema (docs/EXPERIMENTS.md SERVE).
//!
//! Latency quantiles come from a **fixed-bucket** histogram rather than a
//! reservoir: 26 log-spaced buckets (upper bounds 0.25 ms, 0.5 ms, …,
//! doubling per bucket, last bucket ≈ 2.3 h acts as overflow). Recording
//! is one relaxed `fetch_add`; a quantile is the upper bound of the
//! bucket holding the requested rank, so reported p50/p95/p99 are
//! conservative (never under-report) and bounded by the bucket
//! resolution. The same histogram feeds the shed path's
//! `retry_after_ms` estimate.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

use super::cache::ResultCache;

/// Number of latency buckets (fixed at construction; the wire schema
/// exposes the bounds, so consumers never hard-code this).
pub const LATENCY_BUCKETS: usize = 26;

/// A log-spaced fixed-bucket histogram over milliseconds.
///
/// Bucket `i` covers `(bounds[i-1], bounds[i]]` with
/// `bounds[i] = 0.25 * 2^i` ms; the last bucket absorbs overflow.
#[derive(Debug)]
pub struct Histogram {
    bounds_ms: Vec<f64>,
    counts: Vec<AtomicU64>,
}

impl Histogram {
    /// The daemon's latency histogram (26 buckets, 0.25 ms … ≈2.3 h).
    pub fn latency() -> Histogram {
        let bounds_ms: Vec<f64> = (0..LATENCY_BUCKETS)
            .map(|i| 0.25 * (1u64 << i) as f64)
            .collect();
        let counts = (0..LATENCY_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds_ms, counts }
    }

    /// Record one observation (milliseconds). Negative and NaN values
    /// land in the first bucket — they only arise from clock weirdness
    /// and must not panic a worker.
    pub fn record(&self, ms: f64) {
        let i = self
            .bounds_ms
            .iter()
            .position(|&b| ms <= b)
            .unwrap_or(self.bounds_ms.len() - 1);
        self.counts[i].fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// The `q`-quantile (`0 < q <= 1`) as the upper bound of the bucket
    /// holding that rank; `0.0` when empty. Conservative by
    /// construction: the true quantile is never above the returned value
    /// by more than one bucket width.
    pub fn quantile(&self, q: f64) -> f64 {
        let snapshot: Vec<u64> = self
            .counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = snapshot.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in snapshot.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return self.bounds_ms[i];
            }
        }
        *self.bounds_ms.last().unwrap()
    }

    /// Wire form: `{bounds_ms: [...], counts: [...]}` (parallel arrays).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "bounds_ms",
                Json::arr(self.bounds_ms.iter().map(|&b| Json::n(b)).collect()),
            ),
            (
                "counts",
                Json::arr(
                    self.counts
                        .iter()
                        .map(|c| Json::i(c.load(Ordering::Relaxed) as i64))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Shared daemon-wide counters and gauges. One instance per daemon
/// (stdin session or TCP listener), updated by every session and
/// snapshotted by the `stats` request kind.
#[derive(Debug)]
pub struct ServeStats {
    /// Request lines accepted (blank lines excluded; includes lines that
    /// become error/shed responses — every accepted line owns a `seq`).
    pub accepted: AtomicU64,
    /// Responses with `meta.ok == true` (includes `stats` responses).
    pub ok: AtomicU64,
    /// Error responses: evaluation failures, unparsable lines, expired
    /// deadlines, oversized lines, cancellations.
    pub errors: AtomicU64,
    /// Requests refused at admission (subset of neither `ok` nor
    /// `errors`; a shed response is its own disposition).
    pub shed: AtomicU64,
    /// Requests whose `deadline_ms` expired before evaluation began
    /// (subset of `errors`).
    pub deadline_expired: AtomicU64,
    /// Requests answered with a cancellation marker because the session
    /// output died (subset of `errors`).
    pub canceled: AtomicU64,
    /// Responses served from the result cache (any tier).
    pub cache_hits: AtomicU64,
    /// Gauge: admitted requests waiting for a worker.
    pub queued: AtomicU64,
    /// Gauge: requests currently evaluating on a worker.
    pub in_flight: AtomicU64,
    /// Gauge: sessions currently connected.
    pub sessions_active: AtomicU64,
    /// Sessions ever started.
    pub sessions_total: AtomicU64,
    /// Evaluation latency (line arrival → response ready), milliseconds.
    /// Shed / deadline-expired / canceled requests are not recorded —
    /// the histogram measures served work.
    pub latency: Histogram,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats {
            accepted: AtomicU64::new(0),
            ok: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            canceled: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            sessions_active: AtomicU64::new(0),
            sessions_total: AtomicU64::new(0),
            latency: Histogram::latency(),
        }
    }

    /// Snapshot as the `stats` response payload. `cache` is the service's
    /// cache handle (for per-tier counters); `None` renders `cache: null`.
    pub fn to_json(&self, cache: Option<&ResultCache>) -> Json {
        let g = |a: &AtomicU64| Json::i(a.load(Ordering::Relaxed) as i64);
        let responded = self.ok.load(Ordering::Relaxed)
            + self.errors.load(Ordering::Relaxed)
            + self.shed.load(Ordering::Relaxed);
        let cache_json = match cache {
            None => Json::Null,
            Some(c) => Json::obj(vec![
                ("response_hits", g(&self.cache_hits)),
                (
                    "mem",
                    c.memory()
                        .map(|m| m.snapshot().to_json())
                        .unwrap_or(Json::Null),
                ),
            ]),
        };
        Json::obj(vec![
            ("schema", Json::i(1)),
            ("accepted", g(&self.accepted)),
            ("responded", Json::i(responded as i64)),
            ("ok", g(&self.ok)),
            ("errors", g(&self.errors)),
            ("shed", g(&self.shed)),
            ("deadline_expired", g(&self.deadline_expired)),
            ("canceled", g(&self.canceled)),
            ("in_flight", g(&self.in_flight)),
            ("queue_depth", g(&self.queued)),
            (
                "sessions",
                Json::obj(vec![
                    ("active", g(&self.sessions_active)),
                    ("total", g(&self.sessions_total)),
                ]),
            ),
            ("cache", cache_json),
            (
                "latency_ms",
                Json::obj(vec![
                    ("count", Json::i(self.latency.count() as i64)),
                    ("p50", Json::n(self.latency.quantile(0.50))),
                    ("p95", Json::n(self.latency.quantile(0.95))),
                    ("p99", Json::n(self.latency.quantile(0.99))),
                    ("buckets", self.latency.to_json()),
                ]),
            ),
        ])
    }
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats::new()
    }
}

/// Saturating decrement helper for gauges (a gauge must never wrap to
/// u64::MAX on a double-release bug; clamp and keep serving).
pub(crate) fn gauge_dec(gauge: &AtomicU64) {
    let mut cur = gauge.load(Ordering::Relaxed);
    while cur > 0 {
        match gauge.compare_exchange_weak(cur, cur - 1, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_reports_zero() {
        let h = Histogram::latency();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
    }

    #[test]
    fn quantiles_are_bucket_upper_bounds() {
        let h = Histogram::latency();
        // 90 fast (≤0.25ms bucket), 10 slow (~100ms → 128ms bucket).
        for _ in 0..90 {
            h.record(0.1);
        }
        for _ in 0..10 {
            h.record(100.0);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile(0.5), 0.25);
        assert_eq!(h.quantile(0.90), 0.25);
        assert_eq!(h.quantile(0.95), 128.0);
        assert_eq!(h.quantile(0.99), 128.0);
        assert_eq!(h.quantile(1.0), 128.0);
    }

    #[test]
    fn overflow_and_garbage_observations_never_panic() {
        let h = Histogram::latency();
        h.record(f64::NAN);
        h.record(-5.0);
        h.record(1e18); // beyond the last bound → overflow bucket
        assert_eq!(h.count(), 3);
        assert!(h.quantile(1.0) > 0.0);
    }

    #[test]
    fn quantile_monotone_in_q() {
        let h = Histogram::latency();
        for i in 0..1000 {
            h.record(i as f64 / 10.0);
        }
        let (p50, p95, p99) = (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
    }

    #[test]
    fn stats_snapshot_wire_shape() {
        let s = ServeStats::new();
        s.accepted.fetch_add(3, Ordering::Relaxed);
        s.ok.fetch_add(2, Ordering::Relaxed);
        s.shed.fetch_add(1, Ordering::Relaxed);
        s.latency.record(1.0);
        let j = s.to_json(None);
        assert_eq!(j.get("accepted").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("responded").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("shed").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("cache"), Some(&Json::Null));
        let lat = j.get("latency_ms").unwrap();
        assert_eq!(lat.get("count").unwrap().as_u64(), Some(1));
        assert!(lat.get("p50").unwrap().as_f64().unwrap() > 0.0);
        let buckets = lat.get("buckets").unwrap();
        assert_eq!(
            buckets.get("bounds_ms").unwrap().as_arr().unwrap().len(),
            LATENCY_BUCKETS
        );
    }

    #[test]
    fn gauge_dec_saturates_at_zero() {
        let g = AtomicU64::new(1);
        gauge_dec(&g);
        gauge_dec(&g); // would wrap; must clamp
        assert_eq!(g.load(Ordering::Relaxed), 0);
    }
}
