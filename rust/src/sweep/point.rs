//! One cell of a campaign grid ([`SweepPoint`]) and its evaluated record
//! ([`PointResult`]).
//!
//! A point is pure configuration: evaluating it ([`SweepPoint::eval`])
//! runs the *analytic* models — microcode compilation, the
//! architecture-scale PIM model and the GPU roofline — plus, for
//! `conv-exec` points, a deterministic seeded *bit-exact execution* on the
//! crossbar simulator. Neither involves wall-clock measurement (never the
//! measured PJRT series), so a point's result is a deterministic function
//! of its [`SweepPoint::config_json`]. That is what makes the
//! content-addressed result cache ([`super::ResultCache`]) sound.

use anyhow::Result;

use super::campaign::{ArchSpec, GpuBaseline, GpuMode, WorkloadSpec};
use crate::gpumodel::{GpuDtype, Roofline};
use crate::metrics;
use crate::pim::conv;
use crate::pim::matpim::{CnnPimModel, MatmulModel, NumFmt};
use crate::util::json::Json;
use crate::workloads::attention::{decode_workload, DecodeConfig};

/// One point of a sweep campaign: a fully specified (architecture,
/// format, workload, GPU baseline) combination.
///
/// ```
/// use convpim::sweep::Campaign;
/// let points = Campaign::builtin("fig4").unwrap().points();
/// let r = points[0].eval().unwrap(); // fixed8 add, memristive vs exp. A6000
/// assert_eq!(r.unit, "ops/s");
/// assert!(r.improvement() > 100.0); // low-CC ops are PIM's best case
/// ```
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    /// Position in the campaign's expansion order (not part of the cache
    /// identity — reordering a campaign must still hit).
    pub index: usize,
    /// PIM architecture.
    pub arch: ArchSpec,
    /// Number format.
    pub fmt: NumFmt,
    /// Workload.
    pub workload: WorkloadSpec,
    /// GPU baseline.
    pub gpu: GpuBaseline,
}

/// Schema version folded into every point's cache identity. Bump it when
/// the meaning of a stored result changes (new fields, recalibrated
/// models) so stale cache entries miss instead of parsing wrong.
pub const CONFIG_SCHEMA: i64 = 1;

/// Fixed operand seed for `conv-exec` points: the executed result must be
/// a pure function of the point's config (cache soundness), so the seed
/// is a constant, not an input.
const CONV_EXEC_SEED: u64 = 0xC0DE_C04E;

impl SweepPoint {
    /// The canonical configuration document — the cache-key input. Two
    /// points with equal `config_json` are the same experiment by
    /// definition and may share a cached result.
    pub fn config_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::i(CONFIG_SCHEMA)),
            ("arch", self.arch.to_json()),
            ("format", Json::s(self.fmt.name())),
            ("workload", self.workload.to_json()),
            ("gpu", self.gpu.to_json()),
        ])
    }

    /// Parse a point back from its canonical [`SweepPoint::config_json`]
    /// document (the `sweep-point` service-request payload). The schema
    /// version must match [`CONFIG_SCHEMA`]; the reconstructed point's
    /// `config_json` is identical to the input, so a point submitted over
    /// the wire hits exactly the cache entries a `sweep` run stored.
    pub fn from_config_json(config: &Json) -> Result<SweepPoint> {
        let v = config
            .get("v")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("sweep-point config needs a schema version `v`"))?;
        anyhow::ensure!(
            v == CONFIG_SCHEMA as u64,
            "sweep-point config schema v{v} != supported v{CONFIG_SCHEMA}"
        );
        let arch = ArchSpec::from_json(
            config
                .get("arch")
                .ok_or_else(|| anyhow::anyhow!("sweep-point config needs an `arch`"))?,
        )?;
        let fmt_name = config
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("sweep-point config needs a `format`"))?;
        let fmt = super::campaign::fmt_from_name(fmt_name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown format `{fmt_name}` (use fixed8|fixed16|fixed32|fp16|fp32|fp64)"
            )
        })?;
        let workload = WorkloadSpec::from_json(
            config
                .get("workload")
                .ok_or_else(|| anyhow::anyhow!("sweep-point config needs a `workload`"))?,
        )?;
        let gpu = GpuBaseline::from_json(
            config
                .get("gpu")
                .ok_or_else(|| anyhow::anyhow!("sweep-point config needs a `gpu`"))?,
        )?;
        Ok(SweepPoint {
            index: 0,
            arch,
            fmt,
            workload,
            gpu,
        })
    }

    /// Human-readable one-line label.
    pub fn label(&self) -> String {
        format!(
            "{} {} on {} vs {}/{}",
            self.workload.name(),
            self.fmt.name(),
            self.arch.name(),
            self.gpu.gpu.name,
            self.gpu.mode.name()
        )
    }

    /// GPU precision used for this point's roofline lookups: half rates
    /// for ≤16-bit formats (tensor cores for the matmul-shaped CNN work,
    /// the CUDA-core path otherwise), fp32 rates above.
    fn gpu_dtype(&self) -> GpuDtype {
        let half = self.fmt.bits() <= 16;
        match self.workload {
            WorkloadSpec::Cnn { .. } | WorkloadSpec::ConvExec { .. } if half => {
                GpuDtype::F16Tensor
            }
            _ if half => GpuDtype::F16,
            _ => GpuDtype::F32,
        }
    }

    /// Evaluate the point through the analytic models.
    pub fn eval(&self) -> Result<PointResult> {
        // Guard before PimArch::with_dims: a zero dimension would divide
        // by zero in the row-parallelism derivation (a panic would take
        // down the whole batch instead of failing this one point).
        if let Some((r, c)) = self.arch.dims {
            anyhow::ensure!(
                r > 0 && c > 0,
                "crossbar dims must be positive (got {r}x{c})"
            );
        }
        let arch = self.arch.arch();
        let rl = Roofline::new(self.gpu.gpu);
        let dtype = self.gpu_dtype();
        let (cc, pim, gpu_tp, pim_per_watt) = match self.workload {
            WorkloadSpec::Elementwise(op) => {
                // Shared with the registry's Fig. 4 path (metrics::cc_sweep)
                // so the sweep engine reproduces those numbers bit-for-bit.
                let p = metrics::cc_point(self.arch.set, &arch, &rl, self.fmt, op);
                let gpu_tp = match self.gpu.mode {
                    GpuMode::Experimental => p.gpu_ops,
                    GpuMode::Theoretical => rl.peak(dtype),
                };
                (
                    Some(p.cc),
                    p.pim_ops,
                    gpu_tp,
                    p.pim_ops / arch.max_power_w,
                )
            }
            WorkloadSpec::Matmul(n) => {
                anyhow::ensure!(n > 0, "matmul dimension must be positive");
                let mm = MatmulModel::new(n, self.fmt, self.arch.set, arch.cols);
                let gpu_tp = match self.gpu.mode {
                    GpuMode::Experimental => rl.matmul_throughput(n, dtype),
                    GpuMode::Theoretical => rl.matmul_throughput_peak(n, dtype),
                };
                (
                    None,
                    mm.throughput(&arch),
                    gpu_tp,
                    mm.throughput_per_watt(&arch),
                )
            }
            WorkloadSpec::Cnn { model, training } => {
                let base = model.workload();
                let w = if training { base.training() } else { base };
                let macs = w.total_macs();
                let pim_model = CnnPimModel::new(self.fmt, self.arch.set, macs);
                // Batch-64 roofline with traffic scaled by element width —
                // the Fig. 6/7 experimental-GPU model (fp32 scale = 1).
                let scale = self.fmt.bits() as f64 / 32.0;
                let layers: Vec<(f64, f64)> = w
                    .roofline_layers_batched(64.0)
                    .iter()
                    .map(|&(f, b)| (f, b * scale))
                    .collect();
                let gpu_tp = match self.gpu.mode {
                    GpuMode::Experimental => {
                        rl.workload_flops(&layers, dtype) / w.total_flops()
                    }
                    GpuMode::Theoretical => rl.peak(dtype) / w.total_flops(),
                };
                (
                    None,
                    pim_model.throughput(&arch),
                    gpu_tp,
                    pim_model.throughput_per_watt(&arch),
                )
            }
            WorkloadSpec::ConvExec { model, conv, scale } => {
                let w = model.workload();
                let convs = w.conv_layers();
                anyhow::ensure!(
                    conv >= 1 && (conv as usize) <= convs.len(),
                    "{} has {} executable conv layers; `conv` index {conv} is out of range",
                    w.name,
                    convs.len()
                );
                let (layer, full) = convs[conv as usize - 1];
                let spec = full.scaled(scale);
                // Deterministic seeded operands: the executed result must
                // stay a pure function of the point's config (cache
                // soundness), so the seed is a fixed constant.
                let (input, weights) = conv::seeded_operands(&spec, self.fmt, CONV_EXEC_SEED);
                let run = conv::execute_conv(
                    &spec,
                    self.fmt,
                    self.arch.set,
                    &input,
                    &weights,
                    arch.rows as usize,
                )?;
                let reference = conv::reference_conv(&spec, self.fmt, &input, &weights);
                let check = metrics::conv_exec_check(&run, &reference);
                anyhow::ensure!(
                    check.passes(),
                    "executed conv deviates from the analytic model / host reference: {} \
                     (measured {} vs analytic {} cycles/MAC, bit_exact={})",
                    check.label,
                    check.measured_mac_cycles,
                    check.analytic_mac_cycles,
                    check.bit_exact
                );
                // Validated: report the architecture-scale MAC throughput
                // (one MAC per row per mac_cycles) against the layer's
                // batch-64 GPU roofline (FLOPs → MACs via /2) — the same
                // batching formula the Cnn points use, via
                // LayerCost::roofline_batched.
                let pim = arch.throughput_ops(check.analytic_mac_cycles);
                let traffic_scale = self.fmt.bits() as f64 / 32.0;
                let (flops, bytes) = layer.roofline_batched(64.0);
                let pair = (flops, bytes * traffic_scale);
                let gpu_tp = match self.gpu.mode {
                    GpuMode::Experimental => rl.workload_flops(&[pair], dtype) / 2.0,
                    GpuMode::Theoretical => rl.peak(dtype) / 2.0,
                };
                (None, pim, gpu_tp, pim / arch.max_power_w)
            }
            WorkloadSpec::Decode { seq } => {
                anyhow::ensure!(seq > 0, "decode context length must be positive");
                let w = decode_workload(DecodeConfig::llama7b(seq));
                let pim_model = CnnPimModel::new(self.fmt, self.arch.set, w.total_macs());
                // Per-token decode is unbatched matvec work: batch-1
                // roofline, no tensor cores.
                let gpu_tp = match self.gpu.mode {
                    GpuMode::Experimental => {
                        rl.workload_flops(&w.roofline_layers(), dtype) / w.total_flops()
                    }
                    GpuMode::Theoretical => rl.peak(dtype) / w.total_flops(),
                };
                (
                    None,
                    pim_model.throughput(&arch),
                    gpu_tp,
                    pim_model.throughput_per_watt(&arch),
                )
            }
        };
        Ok(PointResult {
            label: self.label(),
            arch: self.arch.name(),
            rows: arch.rows,
            cols: arch.cols,
            format: self.fmt.name(),
            workload: self.workload.name(),
            gpu: self.gpu.gpu.name.to_string(),
            gpu_mode: self.gpu.mode.name().to_string(),
            unit: self.workload.unit().to_string(),
            cc,
            pim,
            gpu_tp,
            pim_per_watt,
            gpu_per_watt: rl.per_watt(gpu_tp),
        })
    }
}

/// The evaluated record of one sweep point — a flat row with a fixed
/// schema, so heterogeneous campaigns still stream into one CSV.
#[derive(Clone, Debug, PartialEq)]
pub struct PointResult {
    /// The point's label ([`SweepPoint::label`]).
    pub label: String,
    /// Architecture name (e.g. `memristive`, `memristive@1024x512`).
    pub arch: String,
    /// Crossbar rows of the evaluated architecture.
    pub rows: u64,
    /// Crossbar columns.
    pub cols: u64,
    /// Number-format name (`fixed32`, `fp16`, …).
    pub format: String,
    /// Workload name (`elementwise-add`, `matmul-n64`, …).
    pub workload: String,
    /// GPU name (`A6000`, …).
    pub gpu: String,
    /// GPU roofline mode (`experimental` / `theoretical`).
    pub gpu_mode: String,
    /// Unit of the two throughput numbers.
    pub unit: String,
    /// Compute complexity in gates/bit (elementwise points only).
    pub cc: Option<f64>,
    /// PIM throughput in `unit`.
    pub pim: f64,
    /// GPU-baseline throughput in `unit`.
    pub gpu_tp: f64,
    /// PIM throughput per watt.
    pub pim_per_watt: f64,
    /// GPU throughput per watt.
    pub gpu_per_watt: f64,
}

impl PointResult {
    /// PIM-over-GPU improvement factor (the Fig. 4 y-axis).
    pub fn improvement(&self) -> f64 {
        self.pim / self.gpu_tp
    }

    /// Machine-readable JSON record (one JSONL line per point).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("point", Json::s(self.label.clone())),
            ("arch", Json::s(self.arch.clone())),
            ("rows", Json::i(self.rows as i64)),
            ("cols", Json::i(self.cols as i64)),
            ("format", Json::s(self.format.clone())),
            ("workload", Json::s(self.workload.clone())),
            ("gpu", Json::s(self.gpu.clone())),
            ("gpu_mode", Json::s(self.gpu_mode.clone())),
            ("unit", Json::s(self.unit.clone())),
            ("cc", self.cc.map(Json::n).unwrap_or(Json::Null)),
            ("pim_throughput", Json::n(self.pim)),
            ("gpu_throughput", Json::n(self.gpu_tp)),
            ("improvement", Json::n(self.improvement())),
            ("pim_per_watt", Json::n(self.pim_per_watt)),
            ("gpu_per_watt", Json::n(self.gpu_per_watt)),
        ])
    }

    /// Rebuild a result from its [`PointResult::to_json`] form (cache
    /// loads). Round-trips exactly: the JSON writer prints floats with
    /// shortest-round-trip formatting. Returns `None` on missing or
    /// mistyped fields.
    pub fn from_json(j: &Json) -> Option<PointResult> {
        let s = |key: &str| Some(j.get(key)?.as_str()?.to_string());
        let f = |key: &str| j.get(key)?.as_f64();
        let cc = match j.get("cc") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64()?),
        };
        Some(PointResult {
            label: s("point")?,
            arch: s("arch")?,
            rows: j.get("rows")?.as_u64()?,
            cols: j.get("cols")?.as_u64()?,
            format: s("format")?,
            workload: s("workload")?,
            gpu: s("gpu")?,
            gpu_mode: s("gpu_mode")?,
            unit: s("unit")?,
            cc,
            pim: f("pim_throughput")?,
            gpu_tp: f("gpu_throughput")?,
            pim_per_watt: f("pim_per_watt")?,
            gpu_per_watt: f("gpu_per_watt")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::Campaign;

    #[test]
    fn config_json_is_stable_and_index_free() {
        let pts = Campaign::builtin("fig4").unwrap().points();
        // Same content at a different index → same config.
        let mut moved = pts[3];
        moved.index = 17;
        assert_eq!(moved.config_json(), pts[3].config_json());
        // Different content → different config.
        assert_ne!(pts[0].config_json(), pts[1].config_json());
        // Deterministic serialization.
        assert_eq!(
            pts[0].config_json().compact(),
            pts[0].config_json().compact()
        );
    }

    #[test]
    fn config_json_round_trips_through_from_config_json() {
        // Every builtin point can be reconstructed from its canonical
        // config — the service's `sweep-point` requests depend on the
        // reconstruction hitting the same cache keys.
        for name in ["fig4", "fig5", "sens-dims", "conv-exec"] {
            for p in Campaign::builtin(name).unwrap().points() {
                let config = p.config_json();
                let back = SweepPoint::from_config_json(&config).unwrap();
                assert_eq!(back.config_json(), config, "{}", p.label());
                assert_eq!(back.label(), p.label());
            }
        }
        // Wrong schema version and missing axes are rejected.
        let mut doc = Campaign::builtin("fig4").unwrap().points()[0].config_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("v".into(), Json::i(999));
        }
        assert!(SweepPoint::from_config_json(&doc).is_err());
        assert!(SweepPoint::from_config_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn result_json_round_trips_exactly() {
        for p in Campaign::builtin("fig5").unwrap().points().iter().take(4) {
            let r = p.eval().unwrap();
            let back = PointResult::from_json(&Json::parse(&r.to_json().compact()).unwrap())
                .unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn elementwise_carries_cc_others_do_not() {
        let fig4 = Campaign::builtin("fig4").unwrap().points();
        assert!(fig4[0].eval().unwrap().cc.is_some());
        let fig5 = Campaign::builtin("fig5").unwrap().points();
        assert!(fig5[0].eval().unwrap().cc.is_none());
    }

    #[test]
    fn zero_dims_error_instead_of_panicking() {
        use crate::pim::gates::GateSet;
        use crate::sweep::ArchSpec;
        let mut p = Campaign::builtin("fig4").unwrap().points()[0];
        p.arch = ArchSpec::with_dims(GateSet::MemristiveNor, 0, 1024);
        let err = p.eval().err().expect("zero rows must fail, not panic");
        assert!(format!("{err}").contains("positive"));
    }

    #[test]
    fn conv_exec_point_validates_execution() {
        // The cheap (fixed8, memristive) cell of the builtin conv-exec
        // campaign: evaluation executes the scaled layer on the simulator
        // and only returns Ok if measured == analytic and output is
        // bit-exact.
        let pts = Campaign::builtin("conv-exec").unwrap().points();
        let p = pts
            .iter()
            .find(|p| p.fmt.name() == "fixed8" && p.arch.name() == "memristive")
            .unwrap();
        let r = p.eval().unwrap();
        assert_eq!(r.unit, "mac/s");
        assert!(r.pim > 0.0 && r.gpu_tp > 0.0);
        assert!(r.cc.is_none());
    }

    #[test]
    fn conv_exec_out_of_range_layer_errors() {
        use crate::sweep::{CnnModel, WorkloadSpec};
        let mut p = Campaign::builtin("conv-exec").unwrap().points()[0];
        p.workload = WorkloadSpec::ConvExec {
            model: CnnModel::AlexNet,
            conv: 99,
            scale: 16,
        };
        let err = p.eval().err().expect("layer index 99 must fail");
        assert!(format!("{err}").contains("out of range"));
    }

    #[test]
    fn theoretical_baseline_is_at_least_experimental() {
        let pts = Campaign::builtin("fig5").unwrap().points();
        // Points come in (experimental, theoretical) pairs per grid cell.
        for pair in pts.chunks(2) {
            let e = pair[0].eval().unwrap();
            let t = pair[1].eval().unwrap();
            assert_eq!(e.workload, t.workload);
            assert!(t.gpu_tp >= e.gpu_tp, "{}: theo < exp", e.label);
        }
    }
}
