//! `convpim loadgen` — a deterministic load generator for the serve
//! daemon, and the first entry in the repo's per-PR perf trajectory
//! (`BENCH_serve.json`).
//!
//! Methodology (following the PIM benchmarking literature's insistence
//! on mixed workload classes and tail-latency reporting rather than
//! one-shot runs — PrIM, arXiv:2105.03814; DAMOV/ML, arXiv:2205.14647):
//!
//! * **Mixed request classes**: a seeded mix of `experiment`,
//!   `sweep-point`, `compare`, `conv-exec`, `list` and `info` requests —
//!   the request *sequence* is a pure function of `(seed, level,
//!   client)`, so two runs replay byte-identical request streams (the
//!   latencies differ; that is the measurement).
//! * **Closed-loop clients at fixed concurrency levels**: each level
//!   spawns N client connections that send one request and wait for its
//!   response before sending the next; per-request wall-clock is the
//!   client-observed round trip.
//! * **Tail latency**: exact p50/p95/p99 over the level's collected
//!   client-side latencies (the daemon's own histogram-bucketed view is
//!   attached under `daemon` from a `stats` request per level).
//!
//! Output schema (`BENCH_serve.json`, see docs/EXPERIMENTS.md LOADGEN):
//!
//! ```text
//! {"bench": "serve", "schema": 1, "seed": S, "requests_per_level": N,
//!  "levels": [{"clients": C, "requests": N, "wall_ms": W, "rps": R,
//!              "p50_ms": ..., "p95_ms": ..., "p99_ms": ...,
//!              "ok": n, "errors": n, "shed": n, "cache_hits": n,
//!              "hit_rate": h, "shed_rate": s, "daemon": {stats payload}}]}
//! ```
//!
//! `hit_rate` is cache hits over *answered* (non-shed) requests;
//! `shed_rate` is shed responses over all requests. The run fails
//! (nonzero exit) when any level degenerates — `rps == 0` or
//! `shed_rate == 1` — after writing the JSON, so CI can both gate on and
//! inspect the artifact.
//!
//! By default the generator self-hosts: it binds `127.0.0.1:0`, runs
//! [`serve_tcp`] in-process with its own service/cache configuration,
//! and tears it down afterwards. `--addr HOST:PORT` targets an external
//! daemon instead (its `--jobs`/`--queue`/cache settings then apply).

use std::io::{BufRead as _, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs as _};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use anyhow::{Context as _, Result};

use super::net::{serve_tcp, wake_listener};
use super::{EvalService, ResultCache};
use crate::sweep::Campaign;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Load-generator configuration (built by the CLI from flags).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Target an external daemon instead of self-hosting.
    pub addr: Option<String>,
    /// Concurrency levels (client counts), one measurement per level.
    pub levels: Vec<usize>,
    /// Requests per level, split across the level's clients.
    pub requests: usize,
    /// Mix seed: the request stream is a pure function of
    /// `(seed, level, client)`.
    pub seed: u64,
    /// Self-hosted daemon: per-session workers (0 = pool-sized).
    pub jobs: usize,
    /// Self-hosted daemon: admission capacity (0 = no shedding).
    pub queue: usize,
    /// Self-hosted daemon: result cache (with any memory tier attached).
    pub cache: Option<ResultCache>,
    /// Where to write `BENCH_serve.json` (`None` = stdout only).
    pub out: Option<PathBuf>,
}

/// Per-client measurement tally.
#[derive(Clone, Debug, Default)]
struct Tally {
    latencies_ms: Vec<f64>,
    ok: usize,
    errors: usize,
    shed: usize,
    cache_hits: usize,
}

impl Tally {
    fn absorb(&mut self, other: Tally) {
        self.latencies_ms.extend(other.latencies_ms);
        self.ok += other.ok;
        self.errors += other.errors;
        self.shed += other.shed;
        self.cache_hits += other.cache_hits;
    }
}

/// Exact percentile over a sorted sample (nearest-rank).
fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let rank = ((q * sorted_ms.len() as f64).ceil() as usize).clamp(1, sorted_ms.len());
    sorted_ms[rank - 1]
}

/// One seeded request line from the mixed-class distribution.
fn mix_request(rng: &mut Rng, points: &[String]) -> String {
    match rng.below(100) {
        // 35% registry experiments (analytic+fast: deterministic, cacheable).
        0..=34 => {
            let ids = ["table1", "fig3", "fig4", "fig5", "fig8"];
            format!(
                "{{\"kind\": \"experiment\", \"id\": \"{}\", \"analytic\": true, \
                 \"fast\": true}}",
                ids[rng.index(ids.len())]
            )
        }
        // 30% sweep points from the paper's fig4 campaign.
        35..=64 => format!(
            "{{\"kind\": \"sweep-point\", \"config\": {}}}",
            points[rng.index(points.len())]
        ),
        // 15% backend comparisons.
        65..=79 => {
            let workloads = ["matmul-n64", "cnn-alexnet"];
            format!(
                "{{\"kind\": \"compare\", \"workload\": \"{}\", \"backends\": \
                 [\"pim:memristive\", \"pim:dram\", \"gpu:a6000:experimental\"]}}",
                workloads[rng.index(workloads.len())]
            )
        }
        // 5% bit-exact conv executions (heavily down-scaled: the class
        // matters for the mix, not the layer size).
        80..=84 => "{\"kind\": \"conv-exec\", \"layer\": \"alexnet:conv2\", \"scale\": 64, \
                    \"set\": \"memristive\", \"fmt\": \"fixed8\"}"
            .to_string(),
        // 10% inventory, 5% system info (cheap control-plane traffic).
        85..=94 => "{\"kind\": \"list\"}".to_string(),
        _ => "{\"kind\": \"info\"}".to_string(),
    }
}

/// One closed-loop client: `n` request/response round trips on one
/// connection, classifying and timing each response.
fn run_client(addr: SocketAddr, seed: u64, n: usize, points: &[String]) -> Result<Tally> {
    let conn = TcpStream::connect(addr)
        .with_context(|| format!("loadgen client connecting to {addr}"))?;
    let mut writer = conn.try_clone().context("cloning client stream")?;
    let mut reader = BufReader::new(conn);
    let mut rng = Rng::new(seed);
    let mut tally = Tally::default();
    let mut line = String::new();
    for _ in 0..n {
        let req = mix_request(&mut rng, points);
        let t0 = Instant::now();
        writer
            .write_all(req.as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .context("writing request")?;
        line.clear();
        let read = reader.read_line(&mut line).context("reading response")?;
        anyhow::ensure!(read > 0, "daemon closed the connection mid-run");
        tally.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        let doc = Json::parse(&line)
            .ok_or_else(|| anyhow::anyhow!("response is not JSON: {line}"))?;
        if doc.get("kind").and_then(Json::as_str) == Some("shed") {
            tally.shed += 1;
        } else if doc
            .get("meta")
            .and_then(|m| m.get("ok"))
            .and_then(Json::as_bool)
            == Some(true)
        {
            tally.ok += 1;
            if doc
                .get("meta")
                .and_then(|m| m.get("cache"))
                .and_then(Json::as_str)
                == Some("hit")
            {
                tally.cache_hits += 1;
            }
        } else {
            tally.errors += 1;
        }
    }
    Ok(tally)
}

/// Snapshot the daemon's own counters (`{"kind": "stats"}` over a fresh
/// connection). Best-effort: `null` when the daemon does not answer.
fn daemon_stats(addr: SocketAddr) -> Json {
    let snapshot = || -> Result<Json> {
        let conn = TcpStream::connect(addr)?;
        let mut writer = conn.try_clone()?;
        let mut reader = BufReader::new(conn);
        writer.write_all(b"{\"kind\": \"stats\"}\n")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let doc = Json::parse(&line).ok_or_else(|| anyhow::anyhow!("bad stats line"))?;
        Ok(doc.get("payload").cloned().unwrap_or(Json::Null))
    };
    snapshot().unwrap_or(Json::Null)
}

/// Run one concurrency level against a live daemon.
fn run_level(
    cfg: &LoadgenConfig,
    addr: SocketAddr,
    level_idx: usize,
    clients: usize,
) -> Result<Json> {
    let clients = clients.max(1);
    let total = cfg.requests.max(clients);
    let points: Vec<String> = Campaign::builtin("fig4")
        .expect("builtin fig4 campaign exists")
        .points()
        .iter()
        .map(|p| p.config_json().compact())
        .collect();

    let t0 = Instant::now();
    let tallies: Result<Vec<Tally>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let share = total / clients + usize::from(c < total % clients);
                // Decorrelate the per-client streams; splitmix64 seeding
                // in `Rng::new` whitens the structured combination.
                let seed = cfg
                    .seed
                    .wrapping_add((level_idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                    .wrapping_add(c as u64 + 1);
                let points = &points;
                scope.spawn(move || run_client(addr, seed, share, points))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen client panicked"))
            .collect()
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut tally = Tally::default();
    for t in tallies? {
        tally.absorb(t);
    }
    tally
        .latencies_ms
        .sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));

    let answered = tally.ok + tally.errors;
    let rps = total as f64 / (wall_ms / 1e3).max(1e-9);
    let hit_rate = tally.cache_hits as f64 / answered.max(1) as f64;
    let shed_rate = tally.shed as f64 / total.max(1) as f64;
    let (p50, p95, p99) = (
        percentile(&tally.latencies_ms, 0.50),
        percentile(&tally.latencies_ms, 0.95),
        percentile(&tally.latencies_ms, 0.99),
    );
    eprintln!(
        "loadgen: {clients} client(s) × {total} request(s): {rps:.1} rps, \
         p50 {p50:.2} ms, p95 {p95:.2} ms, p99 {p99:.2} ms, \
         hit_rate {hit_rate:.2}, shed_rate {shed_rate:.2}"
    );
    Ok(Json::obj(vec![
        ("clients", Json::i(clients as i64)),
        ("requests", Json::i(total as i64)),
        ("wall_ms", Json::n(wall_ms)),
        ("rps", Json::n(rps)),
        ("p50_ms", Json::n(p50)),
        ("p95_ms", Json::n(p95)),
        ("p99_ms", Json::n(p99)),
        ("ok", Json::i(tally.ok as i64)),
        ("errors", Json::i(tally.errors as i64)),
        ("shed", Json::i(tally.shed as i64)),
        ("cache_hits", Json::i(tally.cache_hits as i64)),
        ("hit_rate", Json::n(hit_rate)),
        ("shed_rate", Json::n(shed_rate)),
        ("daemon", daemon_stats(addr)),
    ]))
}

/// Drive every level against the daemon at `addr` and assemble the
/// `BENCH_serve.json` document.
fn drive(cfg: &LoadgenConfig, addr: SocketAddr) -> Result<Json> {
    anyhow::ensure!(!cfg.levels.is_empty(), "loadgen needs at least one concurrency level");
    anyhow::ensure!(cfg.requests > 0, "loadgen needs --requests >= 1");
    let mut levels = Vec::new();
    for (li, &clients) in cfg.levels.iter().enumerate() {
        levels.push(run_level(cfg, addr, li, clients)?);
    }
    Ok(Json::obj(vec![
        ("bench", Json::s("serve")),
        ("schema", Json::i(1)),
        ("seed", Json::i(cfg.seed as i64)),
        ("requests_per_level", Json::i(cfg.requests as i64)),
        ("levels", Json::arr(levels)),
    ]))
}

/// Run the load generator: self-host a TCP daemon (or target
/// `cfg.addr`), measure every level, write `cfg.out`, and fail on a
/// degenerate result (rps 0 or 100% shed) — after writing, so the
/// artifact is always inspectable.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<Json> {
    let doc = match &cfg.addr {
        Some(spec) => {
            let addr = spec
                .to_socket_addrs()
                .with_context(|| format!("resolving --addr {spec}"))?
                .next()
                .ok_or_else(|| anyhow::anyhow!("--addr {spec} resolved to nothing"))?;
            drive(cfg, addr)?
        }
        None => {
            let listener = TcpListener::bind("127.0.0.1:0").context("binding loadgen daemon")?;
            let addr = listener.local_addr()?;
            eprintln!(
                "loadgen: self-hosting daemon on {addr} (jobs {}, queue {}, cache {})",
                cfg.jobs,
                cfg.queue,
                match &cfg.cache {
                    Some(c) => format!("{}", c.dir().display()),
                    None => "disabled".to_string(),
                }
            );
            let service = EvalService::new().with_cache(cfg.cache.clone()).with_jobs(cfg.jobs);
            let stop = AtomicBool::new(false);
            let mut result: Result<Json> = Err(anyhow::anyhow!("loadgen did not run"));
            std::thread::scope(|scope| {
                let daemon =
                    scope.spawn(|| serve_tcp(&service, listener, cfg.jobs, cfg.queue, &stop));
                result = drive(cfg, addr);
                stop.store(true, Ordering::SeqCst);
                wake_listener(addr);
                match daemon.join().expect("daemon thread panicked") {
                    Ok(summary) => eprintln!(
                        "loadgen: daemon served {} session(s), {} request(s)",
                        summary.sessions, summary.totals.requests
                    ),
                    Err(e) => eprintln!("loadgen: daemon error: {e:#}"),
                }
            });
            result?
        }
    };

    if let Some(path) = &cfg.out {
        std::fs::write(path, format!("{}\n", doc.pretty()))
            .with_context(|| format!("writing {}", path.display()))?;
        eprintln!("loadgen: wrote {}", path.display());
    }

    // Gate after writing: a degenerate level fails the run, but the
    // artifact stays on disk for the post-mortem.
    for level in doc.get("levels").and_then(Json::as_arr).unwrap_or(&[]) {
        let rps = level.get("rps").and_then(Json::as_f64).unwrap_or(0.0);
        let shed_rate = level.get("shed_rate").and_then(Json::as_f64).unwrap_or(0.0);
        anyhow::ensure!(
            rps > 0.0,
            "degenerate level (rps == 0): {}",
            level.compact()
        );
        anyhow::ensure!(
            shed_rate < 1.0,
            "degenerate level (everything shed): {}",
            level.compact()
        );
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&s, 0.5), 5.0);
        assert_eq!(percentile(&s, 0.95), 10.0);
        assert_eq!(percentile(&s, 1.0), 10.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn request_mix_is_deterministic_and_valid() {
        let points: Vec<String> = Campaign::builtin("fig4")
            .unwrap()
            .points()
            .iter()
            .map(|p| p.config_json().compact())
            .collect();
        let gen = |seed: u64| -> Vec<String> {
            let mut rng = Rng::new(seed);
            (0..64).map(|_| mix_request(&mut rng, &points)).collect()
        };
        assert_eq!(gen(7), gen(7), "the mix must be a pure function of the seed");
        assert_ne!(gen(7), gen(8));
        // Every generated line is a parsable request of a known kind.
        let mut kinds = std::collections::BTreeSet::new();
        for line in gen(7) {
            let doc = Json::parse(&line).expect("mix lines are JSON");
            let req = crate::service::EvalRequest::from_json(&doc).expect("mix lines parse");
            kinds.insert(req.kind().to_string());
        }
        assert!(kinds.contains("experiment") && kinds.contains("sweep-point"));
    }

    /// A tiny end-to-end run: self-hosted daemon, two levels, schema
    /// checks on the written artifact.
    #[test]
    fn loadgen_end_to_end_writes_schema_compliant_bench() {
        let dir = std::env::temp_dir().join(format!("convpim_loadgen_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let out = dir.join("BENCH_serve.json");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = LoadgenConfig {
            addr: None,
            levels: vec![1, 2],
            requests: 6,
            seed: 1,
            jobs: 2,
            queue: 0,
            cache: Some(ResultCache::new(dir.join("cache")).with_memory(64)),
            out: Some(out.clone()),
        };
        let doc = run_loadgen(&cfg).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("serve"));
        let levels = doc.get("levels").unwrap().as_arr().unwrap();
        assert_eq!(levels.len(), 2);
        for level in levels {
            for key in [
                "clients", "requests", "rps", "p50_ms", "p95_ms", "p99_ms", "hit_rate",
                "shed_rate",
            ] {
                assert!(level.get(key).is_some(), "missing {key}: {}", level.compact());
            }
            let n = level.get("requests").unwrap().as_u64().unwrap();
            let ok = level.get("ok").unwrap().as_u64().unwrap();
            let errors = level.get("errors").unwrap().as_u64().unwrap();
            let shed = level.get("shed").unwrap().as_u64().unwrap();
            assert_eq!(ok + errors + shed, n, "every request is accounted for");
            assert_eq!(errors, 0, "the healthy mix must not error: {}", level.compact());
            assert!(level.get("rps").unwrap().as_f64().unwrap() > 0.0);
            // The daemon snapshot rode along.
            assert!(level.get("daemon").unwrap().get("accepted").is_some());
        }
        // The artifact on disk parses back to the same document.
        let text = std::fs::read_to_string(&out).unwrap();
        assert_eq!(Json::parse(&text).unwrap(), doc);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
