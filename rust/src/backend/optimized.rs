//! [`OptimizedPim`]: the analytic digital-PIM model evaluated over the
//! *synthesized* microcode (`pim-opt:SET[@RxC]`).
//!
//! Identical to [`AnalyticPim`](super::AnalyticPim) arm for arm — same
//! schedules, same throughput/energy expressions — except every scalar
//! cost comes from the equality-saturation synthesizer
//! ([`crate::synth`]) instead of the hand-derived microcode:
//! elementwise workloads evaluate the optimized `Program` itself, and
//! the MatPIM/CNN/decode schedules run over
//! [`optimized_costs`](crate::synth::optimized_costs). Each optimized
//! program is verified bit-identical to the hand microcode (and the
//! scalar oracle) before it is used, and is never costlier, so a
//! `pim-opt` estimate is always ≥ the corresponding `pim` estimate.
//! Comparing the two ids in `convpim compare` (or the sweep `backends`
//! axis) is the experiment: how much headroom the paper's hand microcode
//! leaves on the table.

use anyhow::Result;

use super::{Backend, Estimate};
use crate::metrics;
use crate::pim::arch::PimArch;
use crate::pim::matpim::{CnnPimModel, MatmulModel, NumFmt, ScalarCosts};
use crate::sweep::campaign::{ArchSpec, WorkloadSpec};
use crate::synth::{optimized_costs, optimized_op_program};
use crate::util::json::Json;
use crate::workloads::attention::{decode_workload, DecodeConfig};

/// The synthesized-microcode digital-PIM backend (`pim-opt:SET[@RxC]`).
#[derive(Clone, Debug)]
pub struct OptimizedPim {
    arch: PimArch,
    id: String,
}

impl OptimizedPim {
    /// Wrap an architecture axis value (dims validated by callers, like
    /// [`AnalyticPim::new`](super::AnalyticPim::new)).
    pub fn new(spec: ArchSpec) -> OptimizedPim {
        OptimizedPim {
            arch: spec.arch(),
            id: format!("pim-opt:{}", spec.name()),
        }
    }

    /// The wrapped architecture model.
    pub fn arch(&self) -> &PimArch {
        &self.arch
    }

    fn costs(&self, fmt: NumFmt) -> ScalarCosts {
        optimized_costs(fmt, self.arch.set)
    }
}

impl Backend for OptimizedPim {
    fn id(&self) -> String {
        self.id.clone()
    }

    fn describe(&self) -> String {
        format!(
            "equality-saturated digital-PIM model: {:?} gates, {}x{} crossbars, synthesized microcode (never costlier than pim:*)",
            self.arch.set, self.arch.rows, self.arch.cols
        )
    }

    fn supports(&self, _workload: &WorkloadSpec) -> bool {
        // Same coverage as the analytic backend: every workload kind
        // bottoms out in scalar add/mul costs, all synthesizable.
        true
    }

    fn evaluate(&self, workload: &WorkloadSpec, fmt: NumFmt) -> Result<Estimate> {
        let arch = &self.arch;
        let (throughput, per_watt, cc, notes) = match *workload {
            WorkloadSpec::Elementwise(op) => {
                let opt = optimized_op_program(op, fmt, arch.set);
                let prog = &opt.program;
                let io = metrics::io_bits(op, fmt);
                let cc = metrics::compute_complexity(prog, io);
                let tp = arch.throughput(prog);
                (
                    tp,
                    tp / arch.max_power_w,
                    Some(cc),
                    Json::obj(vec![
                        ("gates", Json::i(prog.gates() as i64)),
                        ("cycles", Json::i(prog.cycles() as i64)),
                        ("io_bits", Json::i(io as i64)),
                        ("baseline_cycles", Json::i(opt.stats.baseline_cycles as i64)),
                        ("improved", Json::Bool(opt.stats.improved)),
                    ]),
                )
            }
            WorkloadSpec::Matmul(n) => {
                anyhow::ensure!(n > 0, "matmul dimension must be positive");
                let mm = MatmulModel::with_costs(n, fmt, arch.set, arch.cols, self.costs(fmt));
                (
                    mm.throughput(arch),
                    mm.throughput_per_watt(arch),
                    None,
                    Json::obj(vec![
                        ("schedule_cycles", Json::i(mm.cycles as i64)),
                        ("rows_per_instance", Json::i(mm.rows_per_instance as i64)),
                    ]),
                )
            }
            WorkloadSpec::Cnn { model, training } => {
                let base = model.workload();
                let w = if training { base.training() } else { base };
                let macs = w.total_macs();
                let pim_model = CnnPimModel::with_costs(fmt, arch.set, macs, self.costs(fmt));
                (
                    pim_model.throughput(arch),
                    pim_model.throughput_per_watt(arch),
                    None,
                    Json::obj(vec![
                        ("macs", Json::n(macs)),
                        ("mac_cycles", Json::i(pim_model.mac_cycles() as i64)),
                    ]),
                )
            }
            WorkloadSpec::ConvExec { model, conv, scale } => {
                let (_, spec) = super::conv_exec_layer(model, conv, scale)?;
                let pim_model =
                    CnnPimModel::with_costs(fmt, arch.set, spec.macs() as f64, self.costs(fmt));
                let tp = arch.throughput_ops(pim_model.mac_cycles());
                (
                    tp,
                    tp / arch.max_power_w,
                    None,
                    Json::obj(vec![
                        ("layer", Json::s(spec.label())),
                        ("macs", Json::i(spec.macs() as i64)),
                        ("mac_cycles", Json::i(pim_model.mac_cycles() as i64)),
                        ("mac_gates", Json::i(pim_model.mac_gates() as i64)),
                        ("executed", Json::Bool(false)),
                    ]),
                )
            }
            WorkloadSpec::NetExec { model, scale } => {
                let graph = crate::pim::netexec::NetGraph::model(model.name(), scale)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "net-exec has no executable graph for `{}`; available: {}",
                            model.name(),
                            crate::pim::netexec::NetGraph::model_names().join(", ")
                        )
                    })?;
                let macs: u64 = graph.layers.iter().map(|l| l.macs()).sum();
                let pim_model =
                    CnnPimModel::with_costs(fmt, arch.set, macs as f64, self.costs(fmt));
                let tp = arch.throughput_ops(pim_model.mac_cycles() * macs.max(1));
                (
                    tp,
                    tp / arch.max_power_w,
                    None,
                    Json::obj(vec![
                        ("graph", Json::s(graph.name.clone())),
                        ("macs", Json::i(macs as i64)),
                        ("mac_cycles", Json::i(pim_model.mac_cycles() as i64)),
                        ("mac_gates", Json::i(pim_model.mac_gates() as i64)),
                        ("executed", Json::Bool(false)),
                    ]),
                )
            }
            WorkloadSpec::Decode { seq } => {
                anyhow::ensure!(seq > 0, "decode context length must be positive");
                let w = decode_workload(DecodeConfig::llama7b(seq));
                let pim_model =
                    CnnPimModel::with_costs(fmt, arch.set, w.total_macs(), self.costs(fmt));
                (
                    pim_model.throughput(arch),
                    pim_model.throughput_per_watt(arch),
                    None,
                    Json::obj(vec![
                        ("macs", Json::n(w.total_macs())),
                        ("mac_cycles", Json::i(pim_model.mac_cycles() as i64)),
                    ]),
                )
            }
        };
        Ok(Estimate {
            backend: self.id.clone(),
            workload: workload.name(),
            format: fmt.name(),
            unit: workload.unit().to_string(),
            throughput,
            per_watt,
            power_w: arch.max_power_w,
            cc,
            bytes_per_unit: None,
            notes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::AnalyticPim;
    use crate::pim::fixed::FixedOp;
    use crate::pim::gates::GateSet;
    use crate::sweep::campaign::CnnModel;

    #[test]
    fn never_slower_than_the_hand_microcode() {
        for set in GateSet::all() {
            let opt = OptimizedPim::new(ArchSpec::paper(set));
            let base = AnalyticPim::new(ArchSpec::paper(set));
            for w in [
                WorkloadSpec::Elementwise(FixedOp::Add),
                WorkloadSpec::Elementwise(FixedOp::Mul),
                WorkloadSpec::Cnn { model: CnnModel::AlexNet, training: false },
            ] {
                let fmt = NumFmt::Fixed(8);
                let eo = opt.evaluate(&w, fmt).unwrap();
                let eb = base.evaluate(&w, fmt).unwrap();
                assert!(
                    eo.throughput >= eb.throughput,
                    "{set:?} {}: opt {} < base {}",
                    w.name(),
                    eo.throughput,
                    eb.throughput
                );
                assert_eq!(eo.unit, eb.unit);
            }
        }
    }

    #[test]
    fn nor_add_is_strictly_faster() {
        // The folded first full adder makes the fixed8 NOR add strictly
        // cheaper, which must surface as strictly higher throughput.
        let opt = OptimizedPim::new(ArchSpec::paper(GateSet::MemristiveNor));
        let base = AnalyticPim::new(ArchSpec::paper(GateSet::MemristiveNor));
        let w = WorkloadSpec::Elementwise(FixedOp::Add);
        let eo = opt.evaluate(&w, NumFmt::Fixed(8)).unwrap();
        let eb = base.evaluate(&w, NumFmt::Fixed(8)).unwrap();
        assert!(eo.throughput > eb.throughput);
        assert_eq!(eo.notes.get("improved").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn id_reflects_dims() {
        assert_eq!(
            OptimizedPim::new(ArchSpec::paper(GateSet::DramMaj)).id(),
            "pim-opt:dram"
        );
        assert_eq!(
            OptimizedPim::new(ArchSpec::with_dims(GateSet::MemristiveNor, 512, 256)).id(),
            "pim-opt:memristive@512x256"
        );
    }
}
