//! Fixed-width text + markdown table rendering for reports and benches.

use crate::util::json::Json;

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                w[i] = w[i].max(cell.chars().count());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(cell);
                for _ in cell.chars().count()..w[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("| ");
        out.push_str(&self.header.join(" | "));
        out.push_str(" |\n|");
        for _ in &self.header {
            out.push_str("---|");
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str("| ");
            out.push_str(&row.join(" | "));
            out.push_str(" |\n");
        }
        out
    }

    /// Serialize as a JSON object (`{"header": [...], "rows": [[...]]}`).
    /// Cells are strings, so the round trip through [`Table::from_json`]
    /// is exact — cached service responses re-render byte-identically.
    pub fn to_json(&self) -> Json {
        let arr = |cells: &[String]| {
            Json::arr(cells.iter().map(|c| Json::s(c.clone())).collect())
        };
        Json::obj(vec![
            ("header", arr(self.header.as_slice())),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| arr(r.as_slice())).collect()),
            ),
        ])
    }

    /// Rebuild a table from its [`Table::to_json`] form. Returns `None` on
    /// missing/mistyped fields or a row whose arity disagrees with the
    /// header.
    pub fn from_json(j: &Json) -> Option<Table> {
        let strings = |v: &Json| -> Option<Vec<String>> {
            v.as_arr()?
                .iter()
                .map(|c| c.as_str().map(str::to_string))
                .collect()
        };
        let header = strings(j.get("header")?)?;
        let rows = j
            .get("rows")?
            .as_arr()?
            .iter()
            .map(|r| strings(r).filter(|cells| cells.len() == header.len()))
            .collect::<Option<Vec<_>>>()?;
        Some(Table { header, rows })
    }

    /// Render as CSV (naive quoting: cells with commas get quoted).
    pub fn csv(&self) -> String {
        let quote = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|c| quote(c))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(&["system", "TOPS"]);
        t.row(vec!["memristive".into(), "233".into()]);
        t.row(vec!["dram".into(), "0.35".into()]);
        t
    }

    #[test]
    fn text_aligns() {
        let s = sample().text();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("system"));
        assert!(lines[2].starts_with("memristive"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().markdown();
        assert!(md.starts_with("| system | TOPS |"));
        assert_eq!(md.lines().count(), 4);
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        Table::new(&["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn json_round_trip_is_exact() {
        let t = sample();
        let back = Table::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.text(), t.text());
        assert_eq!(back.markdown(), t.markdown());
        assert_eq!(back.csv(), t.csv());
    }

    #[test]
    fn from_json_rejects_ragged_rows() {
        let j = Json::parse(r#"{"header": ["a", "b"], "rows": [["1"]]}"#).unwrap();
        assert!(Table::from_json(&j).is_none());
        assert!(Table::from_json(&Json::parse("{}").unwrap()).is_none());
        // Numeric cells are mistyped (cells are strings by contract).
        let j = Json::parse(r#"{"header": ["a"], "rows": [[1]]}"#).unwrap();
        assert!(Table::from_json(&j).is_none());
    }
}
