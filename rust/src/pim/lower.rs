//! Lowering: the precompiled micro-op pipeline behind the packed engine.
//!
//! [`crate::pim::xbar::Crossbar::execute`] used to walk a
//! [`Program`]'s instruction list directly, re-dispatching an opcode
//! `match` over raw column pointers for every instruction of every cache
//! block. This module compiles the program **once** into a dense
//! [`MicroOp`] array that the engine can replay with much less per-step
//! work:
//!
//! * **Peephole fusion.** The microcode compilers in
//!   [`crate::pim::builder`] emit a handful of dominant adjacent pairs —
//!   `NOR2→NOT` / `NOR3→NOT` (the OR and OR3 idioms on the memristive
//!   set), `MAJ3→NOT` (the DRAM NOR idiom), `Set` runs (accumulator
//!   seeding) and `Set→NOT` (constant init + complement), and adjacent
//!   independent `NOT`s (the AND idiom's operand complements). Each such
//!   pair becomes one fused micro-op that writes **both** destination
//!   columns, so the fused pipeline's final crossbar state is bit-identical
//!   to per-instruction execution — fusion halves dispatches and input
//!   reloads without changing a single stored bit.
//! * **Noalias kernels.** The lowering *rejects* (panics on) instructions
//!   that read their own output — the structural hazard
//!   [`Program::validate_for`] reports — and only fuses pairs whose column
//!   sets are disjoint. Every kernel can therefore address its columns as
//!   `&[u64]` / `&mut [u64]` slices, which carry LLVM `noalias` metadata
//!   the old raw-pointer loops could not: the autovectorizer is finally
//!   allowed to emit SIMD for the word loops.
//! * **Word widening.** Kernels process [`LANES`] packed words per step
//!   (explicit load-all-then-store-all bodies), so one step simulates up
//!   to `64 × LANES` row-gates even before threading.
//!
//! Lowering is cached on the [`Program`] (see [`Program::lowered`]) and
//! invalidated by `push`, so tiled executors that replay one compiled
//! program across many crossbars pay the lowering cost once.
//!
//! The unfused per-instruction path survives as
//! [`crate::pim::xbar::Crossbar::execute_serial`], the oracle the fused
//! pipeline is differentially tested against (together with the per-bit
//! [`crate::pim::oracle::ScalarCrossbar`]).

use super::isa::{Col, Instr, Program};

/// Packed `u64` words processed per widened kernel step (4 words = 256
/// simulated rows per step).
pub const LANES: usize = 4;

/// One step of the lowered pipeline: either a single gate instruction or
/// a fused adjacent pair. Fused variants write *every* column the source
/// pair wrote (`t` keeps the intermediate), preserving bit-exactness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MicroOp {
    /// `out = !(a | b)`.
    Nor2 { a: Col, b: Col, out: Col },
    /// `out = !(a | b | c)`.
    Nor3 { a: Col, b: Col, c: Col, out: Col },
    /// `out = !a`.
    Not { a: Col, out: Col },
    /// `out = maj(a, b, c)`.
    Maj3 { a: Col, b: Col, c: Col, out: Col },
    /// `out = a`.
    Copy { a: Col, out: Col },
    /// `out = bit`.
    Set { out: Col, bit: bool },
    /// `t = !(a | b); out = !t` — the OR idiom.
    Nor2Not { a: Col, b: Col, t: Col, out: Col },
    /// `t = !(a | b | c); out = !t` — the OR3 idiom.
    Nor3Not { a: Col, b: Col, c: Col, t: Col, out: Col },
    /// `t = maj(a, b, c); out = !t` — the DRAM NOR idiom.
    Maj3Not { a: Col, b: Col, c: Col, t: Col, out: Col },
    /// Two independent NOTs (the AND idiom's operand complements).
    Not2 { a: Col, out_a: Col, b: Col, out_b: Col },
    /// Two column initializations (accumulator seeding, `Set→NOT`).
    Set2 { out_a: Col, bit_a: bool, out_b: Col, bit_b: bool },
}

impl MicroOp {
    /// True when this micro-op covers two source instructions.
    pub fn is_fused(&self) -> bool {
        matches!(
            self,
            MicroOp::Nor2Not { .. }
                | MicroOp::Nor3Not { .. }
                | MicroOp::Maj3Not { .. }
                | MicroOp::Not2 { .. }
                | MicroOp::Set2 { .. }
        )
    }
}

/// A program lowered to its dense micro-op pipeline.
#[derive(Clone, Debug, Default)]
pub struct Lowered {
    ops: Vec<MicroOp>,
    width: Col,
    source_len: usize,
}

impl Lowered {
    /// The micro-op sequence.
    #[inline]
    pub fn ops(&self) -> &[MicroOp] {
        &self.ops
    }

    /// Number of micro-ops (≤ source instructions; the gap is fusion).
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the pipeline is empty.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Number of source instructions this pipeline was lowered from.
    pub fn source_len(&self) -> usize {
        self.source_len
    }

    /// Number of fused micro-ops (each stands for two instructions).
    pub fn fused(&self) -> usize {
        self.ops.iter().filter(|op| op.is_fused()).count()
    }

    /// Minimum crossbar width (columns) needed to run the pipeline.
    pub fn width(&self) -> Col {
        self.width
    }
}

/// Lower a program into its micro-op pipeline.
///
/// # Panics
///
/// Panics when an instruction reads its own output column — such a
/// program is invalid for any stateful-logic hardware (see
/// [`Program::validate_for`]), and the noalias kernels require the
/// guarantee unconditionally, not just under `debug_assertions`.
pub fn lower(prog: &Program) -> Lowered {
    let instrs = prog.instrs();
    for (i, instr) in instrs.iter().enumerate() {
        assert!(
            !instr.inputs().any(|c| c == instr.out()),
            "instr {i} ({instr:?}) reads its own output; \
             run Program::validate_for before executing"
        );
    }
    let mut ops = Vec::with_capacity(instrs.len());
    let mut i = 0;
    while i < instrs.len() {
        if i + 1 < instrs.len() {
            if let Some(op) = fuse_pair(instrs[i], instrs[i + 1]) {
                ops.push(op);
                i += 2;
                continue;
            }
        }
        ops.push(single(instrs[i]));
        i += 1;
    }
    Lowered {
        ops,
        width: prog.width(),
        source_len: instrs.len(),
    }
}

/// 1:1 lowering of one instruction.
fn single(instr: Instr) -> MicroOp {
    match instr {
        Instr::Nor2 { a, b, out } => MicroOp::Nor2 { a, b, out },
        Instr::Nor3 { a, b, c, out } => MicroOp::Nor3 { a, b, c, out },
        Instr::Not { a, out } => MicroOp::Not { a, out },
        Instr::Maj3 { a, b, c, out } => MicroOp::Maj3 { a, b, c, out },
        Instr::Copy { a, out } => MicroOp::Copy { a, out },
        Instr::Set { out, bit } => MicroOp::Set { out, bit },
    }
}

/// Try to fuse two adjacent instructions into one micro-op.
///
/// A fusion is only taken when it is unconditionally bit-exact **and**
/// keeps every simultaneously-borrowed column distinct (the noalias
/// requirement): the second op must read exactly the first op's output
/// (serial fusions) or nothing of it (parallel fusions), and no output
/// may alias any other named column of the pair.
fn fuse_pair(first: Instr, second: Instr) -> Option<MicroOp> {
    use Instr::*;
    match (first, second) {
        // Gate → NOT of its result: the OR / OR3 / DRAM-NOR idioms.
        (Nor2 { a, b, out: t }, Not { a: na, out }) if na == t && out != a && out != b => {
            Some(MicroOp::Nor2Not { a, b, t, out })
        }
        (Nor3 { a, b, c, out: t }, Not { a: na, out })
            if na == t && out != a && out != b && out != c =>
        {
            Some(MicroOp::Nor3Not { a, b, c, t, out })
        }
        (Maj3 { a, b, c, out: t }, Not { a: na, out })
            if na == t && out != a && out != b && out != c =>
        {
            Some(MicroOp::Maj3Not { a, b, c, t, out })
        }
        // Set → NOT of the constant: both destinations are constants.
        (Set { out: t, bit }, Not { a: na, out }) if na == t => Some(MicroOp::Set2 {
            out_a: t,
            bit_a: bit,
            out_b: out,
            bit_b: !bit,
        }),
        // Adjacent initializations (accumulator / constant seeding).
        (Set { out: oa, bit: ba }, Set { out: ob, bit: bb }) if oa != ob => {
            Some(MicroOp::Set2 {
                out_a: oa,
                bit_a: ba,
                out_b: ob,
                bit_b: bb,
            })
        }
        // Two independent NOTs (the AND idiom's operand complements).
        // `b != oa` excludes the dependent NOT→NOT chain; the output
        // constraints keep the four borrowed columns alias-free.
        (Not { a, out: oa }, Not { a: b, out: ob })
            if b != oa && ob != oa && ob != a =>
        {
            Some(MicroOp::Not2 { a, out_a: oa, b, out_b: ob })
        }
        _ => None,
    }
}

// ---- widened kernels ----------------------------------------------------

/// The all-ones / all-zeros word for a constant column.
#[inline]
fn splat(bit: bool) -> u64 {
    if bit {
        u64::MAX
    } else {
        0
    }
}

#[inline]
fn fill(out: &mut [u64], v: u64) {
    for w in out.iter_mut() {
        *w = v;
    }
}

#[inline]
fn map1(out: &mut [u64], a: &[u64], f: impl Fn(u64) -> u64) {
    let n = out.len();
    let a = &a[..n];
    let mut i = 0;
    while i + LANES <= n {
        let mut v = [0u64; LANES];
        for k in 0..LANES {
            v[k] = f(a[i + k]);
        }
        out[i..i + LANES].copy_from_slice(&v);
        i += LANES;
    }
    while i < n {
        out[i] = f(a[i]);
        i += 1;
    }
}

#[inline]
fn map2(out: &mut [u64], a: &[u64], b: &[u64], f: impl Fn(u64, u64) -> u64) {
    let n = out.len();
    let (a, b) = (&a[..n], &b[..n]);
    let mut i = 0;
    while i + LANES <= n {
        let mut v = [0u64; LANES];
        for k in 0..LANES {
            v[k] = f(a[i + k], b[i + k]);
        }
        out[i..i + LANES].copy_from_slice(&v);
        i += LANES;
    }
    while i < n {
        out[i] = f(a[i], b[i]);
        i += 1;
    }
}

#[inline]
fn map3(out: &mut [u64], a: &[u64], b: &[u64], c: &[u64], f: impl Fn(u64, u64, u64) -> u64) {
    let n = out.len();
    let (a, b, c) = (&a[..n], &b[..n], &c[..n]);
    let mut i = 0;
    while i + LANES <= n {
        let mut v = [0u64; LANES];
        for k in 0..LANES {
            v[k] = f(a[i + k], b[i + k], c[i + k]);
        }
        out[i..i + LANES].copy_from_slice(&v);
        i += LANES;
    }
    while i < n {
        out[i] = f(a[i], b[i], c[i]);
        i += 1;
    }
}

/// Fused two-output kernel: `t = f(a, b)`, `out = !f(a, b)`.
#[inline]
fn map2x2(t: &mut [u64], out: &mut [u64], a: &[u64], b: &[u64], f: impl Fn(u64, u64) -> u64) {
    let n = t.len();
    let (a, b) = (&a[..n], &b[..n]);
    let out = &mut out[..n];
    let mut i = 0;
    while i + LANES <= n {
        let mut v = [0u64; LANES];
        for k in 0..LANES {
            v[k] = f(a[i + k], b[i + k]);
        }
        t[i..i + LANES].copy_from_slice(&v);
        for k in 0..LANES {
            v[k] = !v[k];
        }
        out[i..i + LANES].copy_from_slice(&v);
        i += LANES;
    }
    while i < n {
        let v = f(a[i], b[i]);
        t[i] = v;
        out[i] = !v;
        i += 1;
    }
}

/// Fused two-output kernel: `t = f(a, b, c)`, `out = !f(a, b, c)`.
#[inline]
fn map3x2(
    t: &mut [u64],
    out: &mut [u64],
    a: &[u64],
    b: &[u64],
    c: &[u64],
    f: impl Fn(u64, u64, u64) -> u64,
) {
    let n = t.len();
    let (a, b, c) = (&a[..n], &b[..n], &c[..n]);
    let out = &mut out[..n];
    let mut i = 0;
    while i + LANES <= n {
        let mut v = [0u64; LANES];
        for k in 0..LANES {
            v[k] = f(a[i + k], b[i + k], c[i + k]);
        }
        t[i..i + LANES].copy_from_slice(&v);
        for k in 0..LANES {
            v[k] = !v[k];
        }
        out[i..i + LANES].copy_from_slice(&v);
        i += LANES;
    }
    while i < n {
        let v = f(a[i], b[i], c[i]);
        t[i] = v;
        out[i] = !v;
        i += 1;
    }
}

/// Borrow the word range `[w0, w0+len)` of column `c` as a shared slice.
///
/// # Safety
///
/// `base` must point to a live column-major allocation at `wpc` words per
/// column covering column `c`; the range must not be mutably borrowed.
#[inline]
unsafe fn rd<'a>(base: *const u64, wpc: usize, c: Col, w0: usize, len: usize) -> &'a [u64] {
    unsafe { std::slice::from_raw_parts(base.add(c as usize * wpc + w0), len) }
}

/// Borrow the word range `[w0, w0+len)` of column `c` as a mutable slice.
///
/// # Safety
///
/// As [`rd`], and the range must not be borrowed at all elsewhere.
#[inline]
unsafe fn wr<'a>(base: *mut u64, wpc: usize, c: Col, w0: usize, len: usize) -> &'a mut [u64] {
    unsafe { std::slice::from_raw_parts_mut(base.add(c as usize * wpc + w0), len) }
}

impl MicroOp {
    /// Execute this micro-op over the word range `[w0, w1)` of every
    /// column it names.
    ///
    /// # Safety
    ///
    /// * `base` must point to a live column-major allocation covering
    ///   every column named by `self` at `wpc` words per column;
    /// * `w0 <= w1 <= wpc`;
    /// * `self` must come from [`lower`] (its invariants — outputs
    ///   distinct from inputs and co-outputs — are what make the
    ///   shared/mutable slice borrows below alias-free);
    /// * no other thread may concurrently access word indices `[w0, w1)`
    ///   of any column.
    pub(crate) unsafe fn apply(self, base: *mut u64, wpc: usize, w0: usize, w1: usize) {
        let len = w1 - w0;
        let cbase = base as *const u64;
        // SAFETY: caller contract plus the lowering invariants: every
        // `wr` column below is distinct from every `rd` column and from
        // any co-`wr` column of the same micro-op.
        unsafe {
            match self {
                MicroOp::Nor2 { a, b, out } => map2(
                    wr(base, wpc, out, w0, len),
                    rd(cbase, wpc, a, w0, len),
                    rd(cbase, wpc, b, w0, len),
                    |x, y| !(x | y),
                ),
                MicroOp::Nor3 { a, b, c, out } => map3(
                    wr(base, wpc, out, w0, len),
                    rd(cbase, wpc, a, w0, len),
                    rd(cbase, wpc, b, w0, len),
                    rd(cbase, wpc, c, w0, len),
                    |x, y, z| !(x | y | z),
                ),
                MicroOp::Not { a, out } => {
                    map1(wr(base, wpc, out, w0, len), rd(cbase, wpc, a, w0, len), |x| !x)
                }
                MicroOp::Maj3 { a, b, c, out } => map3(
                    wr(base, wpc, out, w0, len),
                    rd(cbase, wpc, a, w0, len),
                    rd(cbase, wpc, b, w0, len),
                    rd(cbase, wpc, c, w0, len),
                    |x, y, z| (x & y) | (z & (x | y)),
                ),
                MicroOp::Copy { a, out } => wr(base, wpc, out, w0, len)
                    .copy_from_slice(rd(cbase, wpc, a, w0, len)),
                MicroOp::Set { out, bit } => fill(wr(base, wpc, out, w0, len), splat(bit)),
                MicroOp::Nor2Not { a, b, t, out } => map2x2(
                    wr(base, wpc, t, w0, len),
                    wr(base, wpc, out, w0, len),
                    rd(cbase, wpc, a, w0, len),
                    rd(cbase, wpc, b, w0, len),
                    |x, y| !(x | y),
                ),
                MicroOp::Nor3Not { a, b, c, t, out } => map3x2(
                    wr(base, wpc, t, w0, len),
                    wr(base, wpc, out, w0, len),
                    rd(cbase, wpc, a, w0, len),
                    rd(cbase, wpc, b, w0, len),
                    rd(cbase, wpc, c, w0, len),
                    |x, y, z| !(x | y | z),
                ),
                MicroOp::Maj3Not { a, b, c, t, out } => map3x2(
                    wr(base, wpc, t, w0, len),
                    wr(base, wpc, out, w0, len),
                    rd(cbase, wpc, a, w0, len),
                    rd(cbase, wpc, b, w0, len),
                    rd(cbase, wpc, c, w0, len),
                    |x, y, z| (x & y) | (z & (x | y)),
                ),
                MicroOp::Not2 { a, out_a, b, out_b } => {
                    map1(wr(base, wpc, out_a, w0, len), rd(cbase, wpc, a, w0, len), |x| !x);
                    map1(wr(base, wpc, out_b, w0, len), rd(cbase, wpc, b, w0, len), |x| !x);
                }
                MicroOp::Set2 { out_a, bit_a, out_b, bit_b } => {
                    fill(wr(base, wpc, out_a, w0, len), splat(bit_a));
                    fill(wr(base, wpc, out_b, w0, len), splat(bit_b));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::gates::GateSet;
    use crate::pim::oracle::ScalarCrossbar;
    use crate::pim::xbar::Crossbar;
    use crate::util::rng::Rng;

    /// Run `prog` through the fused, unfused and per-bit engines from the
    /// same seeded state and require bit-identical results everywhere.
    fn assert_all_engines_agree(prog: &Program, rows: usize, seed: u64) {
        let cols = prog.width().max(1) as usize;
        let mut rng = Rng::new(seed);
        let mut fused = Crossbar::new(rows, cols);
        let mut serial = Crossbar::new(rows, cols);
        let mut oracle = ScalarCrossbar::new(rows, cols);
        for c in 0..cols as Col {
            for r in 0..rows {
                let bit = rng.bool();
                fused.set(r, c, bit);
                serial.set(r, c, bit);
                oracle.set(r, c, bit);
            }
        }
        fused.execute_fused(prog);
        serial.execute_serial(prog);
        oracle.execute(prog);
        assert!(oracle.agrees_with(&serial), "serial path vs oracle");
        assert!(oracle.agrees_with(&fused), "fused path vs oracle");
        assert_eq!(fused.row_gates(), serial.row_gates(), "gate accounting");
    }

    #[test]
    fn or_idiom_fuses_to_one_micro_op() {
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Nor2 { a: 0, b: 1, out: 2 });
        p.push(Instr::Not { a: 2, out: 3 });
        let low = lower(&p);
        assert_eq!(low.len(), 1);
        assert_eq!(
            low.ops()[0],
            MicroOp::Nor2Not { a: 0, b: 1, t: 2, out: 3 }
        );
        assert_eq!(low.fused(), 1);
        assert_eq!(low.source_len(), 2);
        assert_all_engines_agree(&p, 150, 1);
    }

    #[test]
    fn set_run_and_set_not_fuse() {
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Set { out: 0, bit: false });
        p.push(Instr::Set { out: 1, bit: true });
        p.push(Instr::Set { out: 2, bit: true });
        p.push(Instr::Not { a: 2, out: 3 });
        let low = lower(&p);
        assert_eq!(low.len(), 2);
        assert_eq!(
            low.ops()[1],
            MicroOp::Set2 { out_a: 2, bit_a: true, out_b: 3, bit_b: false }
        );
        assert_all_engines_agree(&p, 70, 2);
    }

    #[test]
    fn and_idiom_complements_fuse_as_not2() {
        // Builder's AND on the NOR set: NOT a, NOT b, NOR2.
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Not { a: 0, out: 2 });
        p.push(Instr::Not { a: 1, out: 3 });
        p.push(Instr::Nor2 { a: 2, b: 3, out: 4 });
        let low = lower(&p);
        assert_eq!(low.len(), 2);
        assert_eq!(
            low.ops()[0],
            MicroOp::Not2 { a: 0, out_a: 2, b: 1, out_b: 3 }
        );
        assert_all_engines_agree(&p, 129, 3);
    }

    #[test]
    fn aliasing_pairs_are_not_fused_and_stay_exact() {
        // NOT output aliases the NOR's input: fusing would violate the
        // noalias kernel contract, so the pair must stay unfused — and
        // still execute bit-exactly.
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Nor2 { a: 0, b: 1, out: 2 });
        p.push(Instr::Not { a: 2, out: 0 });
        let low = lower(&p);
        assert_eq!(low.len(), 2);
        assert_eq!(low.fused(), 0);
        assert_all_engines_agree(&p, 150, 4);

        // Dependent NOT→NOT chain is never fused.
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Not { a: 0, out: 1 });
        p.push(Instr::Not { a: 1, out: 2 });
        let low = lower(&p);
        assert_eq!(low.len(), 2);
        assert_all_engines_agree(&p, 150, 5);

        // Second NOT writing over the first NOT's source reads stale
        // data if fused with loads hoisted — excluded by the `ob != a`
        // guard, covered here.
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Not { a: 0, out: 1 });
        p.push(Instr::Not { a: 2, out: 0 });
        let low = lower(&p);
        assert_eq!(low.fused(), 0);
        assert_all_engines_agree(&p, 150, 6);
    }

    #[test]
    #[should_panic(expected = "reads its own output")]
    fn lowering_rejects_in_place_instructions() {
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Nor2 { a: 0, b: 2, out: 2 });
        lower(&p);
    }

    #[test]
    fn widened_kernels_cover_remainder_tails() {
        // Rows chosen so wpc is not a multiple of LANES and the last word
        // is partial: the tail loops must produce the same bits.
        for rows in [1usize, 63, 64, 65, 64 * LANES + 7, 64 * (LANES + 1) + 1] {
            let mut p = Program::new(GateSet::MemristiveNor);
            p.push(Instr::Nor2 { a: 0, b: 1, out: 2 });
            p.push(Instr::Not { a: 2, out: 3 });
            p.push(Instr::Nor3 { a: 0, b: 1, c: 3, out: 4 });
            p.push(Instr::Maj3 { a: 0, b: 1, c: 4, out: 5 });
            p.push(Instr::Not { a: 5, out: 6 });
            assert_all_engines_agree(&p, rows, rows as u64);
        }
    }
}
