//! Bit-packed crossbar state and the column-parallel execution engine.
//!
//! The crossbar is an `rows × cols` binary matrix. Storage is
//! **column-major and bit-packed**: column `j` is `ceil(rows/64)`
//! consecutive `u64` words, so one column-parallel gate (the O(1)
//! operation of the abstract PIM model) becomes a short loop of word-wise
//! bit operations — `rows` simulated row-gates per `words_per_col` CPU ops.
//! This loop is the simulator's hot path and the target of the §Perf pass.

use super::isa::{Col, Instr, Program};

/// A simulated crossbar array.
#[derive(Clone, Debug)]
pub struct Crossbar {
    rows: usize,
    cols: usize,
    wpc: usize,
    /// Column-major packed bits; column j at `data[j*wpc .. (j+1)*wpc]`.
    data: Vec<u64>,
    /// Total row-gates executed (for throughput accounting in benches).
    row_gates: u64,
}

impl Crossbar {
    /// Create a zeroed crossbar.
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows > 0 && cols > 0);
        let wpc = rows.div_ceil(64);
        Crossbar {
            rows,
            cols,
            wpc,
            data: vec![0; wpc * cols],
            row_gates: 0,
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-gates executed so far (rows × gate instructions).
    pub fn row_gates(&self) -> u64 {
        self.row_gates
    }

    /// Reset the row-gate counter.
    pub fn reset_row_gates(&mut self) {
        self.row_gates = 0;
    }

    #[inline]
    fn col(&self, j: Col) -> &[u64] {
        let j = j as usize;
        debug_assert!(j < self.cols, "column {j} out of range {}", self.cols);
        &self.data[j * self.wpc..(j + 1) * self.wpc]
    }

    /// Read one bit.
    pub fn get(&self, row: usize, col: Col) -> bool {
        debug_assert!(row < self.rows);
        (self.col(col)[row / 64] >> (row % 64)) & 1 == 1
    }

    /// Write one bit (host data-load path, not a PIM operation).
    pub fn set(&mut self, row: usize, col: Col, bit: bool) {
        debug_assert!(row < self.rows);
        let wpc = self.wpc;
        let w = &mut self.data[col as usize * wpc + row / 64];
        if bit {
            *w |= 1 << (row % 64);
        } else {
            *w &= !(1 << (row % 64));
        }
    }

    /// Load an N-bit value into columns `[base, base+bits)` of `row`,
    /// little-endian (bit k of `value` → column `base+k`).
    pub fn write_value(&mut self, row: usize, base: Col, bits: u32, value: u64) {
        for k in 0..bits {
            self.set(row, base + k, (value >> k) & 1 == 1);
        }
    }

    /// Read an N-bit little-endian value from columns `[base, base+bits)`.
    pub fn read_value(&self, row: usize, base: Col, bits: u32) -> u64 {
        let mut v = 0u64;
        for k in 0..bits {
            if self.get(row, base + k) {
                v |= 1 << k;
            }
        }
        v
    }

    /// Bulk-load one value per row into a bit-field (column-transpose).
    pub fn write_field(&mut self, base: Col, bits: u32, values: &[u64]) {
        assert!(values.len() <= self.rows);
        // Transpose in 64-row blocks: gather bit k of 64 values into one
        // word of column base+k.
        for (block, chunk) in values.chunks(64).enumerate() {
            for k in 0..bits {
                let mut word = 0u64;
                for (i, &v) in chunk.iter().enumerate() {
                    word |= ((v >> k) & 1) << i;
                }
                let col = (base + k) as usize;
                self.data[col * self.wpc + block] = word;
            }
        }
    }

    /// Bulk-read `n` per-row values from a bit-field.
    pub fn read_field(&self, base: Col, bits: u32, n: usize) -> Vec<u64> {
        assert!(n <= self.rows);
        let mut out = vec![0u64; n];
        for k in 0..bits {
            let col = self.col(base + k);
            for (block, &word) in col.iter().enumerate() {
                let lo = block * 64;
                if lo >= n {
                    break;
                }
                let hi = (lo + 64).min(n);
                let mut w = word;
                for item in out.iter_mut().take(hi).skip(lo) {
                    if w & 1 == 1 {
                        *item |= 1 << k;
                    }
                    w >>= 1;
                }
            }
        }
        out
    }

    /// Borrow one input column as a raw slice (no allocation; §Perf: the
    /// original helper built a `Vec` of slices *per instruction*, which
    /// dominated short-column programs).
    #[inline(always)]
    fn col_in(&self, c: Col) -> &[u64] {
        let c = c as usize;
        debug_assert!(c < self.cols);
        // SAFETY: in-bounds (debug-asserted; columns validated at program
        // construction) and only aliased immutably.
        unsafe { std::slice::from_raw_parts(self.data.as_ptr().add(c * self.wpc), self.wpc) }
    }

    /// Borrow the output column mutably.
    ///
    /// SAFETY contract: `out` must differ from every input column of the
    /// executing instruction (enforced by `Program::validate_for` and
    /// debug-asserted in `step`).
    #[inline(always)]
    fn col_out(&mut self, out: Col) -> &mut [u64] {
        let o = out as usize;
        debug_assert!(o < self.cols);
        unsafe {
            std::slice::from_raw_parts_mut(self.data.as_mut_ptr().add(o * self.wpc), self.wpc)
        }
    }

    /// Execute one instruction (column-parallel across all rows).
    #[inline]
    pub fn step(&mut self, instr: Instr) {
        self.step_full(instr);
        if instr.is_gate() {
            self.row_gates += self.rows as u64;
        }
    }

    /// Full-width single-instruction execution (§Perf: kept separate from
    /// the blocked `step_range` because constant-zero offsets still cost
    /// ~2x on short columns — LLVM unrolls the fixed-bound loops here).
    #[inline]
    fn step_full(&mut self, instr: Instr) {
        match instr {
            Instr::Nor2 { a, b, out } => {
                debug_assert!(a != out && b != out);
                let (a, b) = (self.col_in(a).as_ptr(), self.col_in(b).as_ptr());
                let o = self.col_out(out);
                for (i, oi) in o.iter_mut().enumerate() {
                    // SAFETY: i < wpc; inputs are wpc-word columns.
                    *oi = unsafe { !(*a.add(i) | *b.add(i)) };
                }
            }
            Instr::Nor3 { a, b, c, out } => {
                debug_assert!(a != out && b != out && c != out);
                let (a, b, c) = (
                    self.col_in(a).as_ptr(),
                    self.col_in(b).as_ptr(),
                    self.col_in(c).as_ptr(),
                );
                let o = self.col_out(out);
                for (i, oi) in o.iter_mut().enumerate() {
                    *oi = unsafe { !(*a.add(i) | *b.add(i) | *c.add(i)) };
                }
            }
            Instr::Not { a, out } => {
                debug_assert!(a != out);
                let a = self.col_in(a).as_ptr();
                let o = self.col_out(out);
                for (i, oi) in o.iter_mut().enumerate() {
                    *oi = unsafe { !*a.add(i) };
                }
            }
            Instr::Maj3 { a, b, c, out } => {
                debug_assert!(a != out && b != out && c != out);
                let (a, b, c) = (
                    self.col_in(a).as_ptr(),
                    self.col_in(b).as_ptr(),
                    self.col_in(c).as_ptr(),
                );
                let o = self.col_out(out);
                for (i, oi) in o.iter_mut().enumerate() {
                    let (x, y, z) = unsafe { (*a.add(i), *b.add(i), *c.add(i)) };
                    *oi = (x & y) | (z & (x | y));
                }
            }
            Instr::Copy { a, out } => {
                debug_assert!(a != out);
                let a = self.col_in(a).as_ptr();
                let o = self.col_out(out);
                for (i, oi) in o.iter_mut().enumerate() {
                    *oi = unsafe { *a.add(i) };
                }
            }
            Instr::Set { out, bit } => {
                self.col_out(out).fill(if bit { u64::MAX } else { 0 });
            }
        }
    }

    /// Execute one instruction over the word range `[w0, w1)` of every
    /// column (the cache-blocked inner loop; no gate accounting here).
    #[inline]
    fn step_range(&mut self, instr: Instr, w0: usize, w1: usize) {
        match instr {
            Instr::Nor2 { a, b, out } => {
                debug_assert!(a != out && b != out);
                // SAFETY: offsets < wpc; columns are wpc words long.
                let (a, b) = unsafe {
                    (self.col_in(a).as_ptr().add(w0), self.col_in(b).as_ptr().add(w0))
                };
                let o = &mut self.col_out(out)[w0..w1];
                for (i, oi) in o.iter_mut().enumerate() {
                    *oi = unsafe { !(*a.add(i) | *b.add(i)) };
                }
            }
            Instr::Nor3 { a, b, c, out } => {
                debug_assert!(a != out && b != out && c != out);
                let (a, b, c) = unsafe {
                    (
                        self.col_in(a).as_ptr().add(w0),
                        self.col_in(b).as_ptr().add(w0),
                        self.col_in(c).as_ptr().add(w0),
                    )
                };
                let o = &mut self.col_out(out)[w0..w1];
                for (i, oi) in o.iter_mut().enumerate() {
                    *oi = unsafe { !(*a.add(i) | *b.add(i) | *c.add(i)) };
                }
            }
            Instr::Not { a, out } => {
                debug_assert!(a != out);
                let a = unsafe { self.col_in(a).as_ptr().add(w0) };
                let o = &mut self.col_out(out)[w0..w1];
                for (i, oi) in o.iter_mut().enumerate() {
                    *oi = unsafe { !*a.add(i) };
                }
            }
            Instr::Maj3 { a, b, c, out } => {
                debug_assert!(a != out && b != out && c != out);
                let (a, b, c) = unsafe {
                    (
                        self.col_in(a).as_ptr().add(w0),
                        self.col_in(b).as_ptr().add(w0),
                        self.col_in(c).as_ptr().add(w0),
                    )
                };
                let o = &mut self.col_out(out)[w0..w1];
                for (i, oi) in o.iter_mut().enumerate() {
                    let (x, y, z) = unsafe { (*a.add(i), *b.add(i), *c.add(i)) };
                    *oi = (x & y) | (z & (x | y));
                }
            }
            Instr::Copy { a, out } => {
                debug_assert!(a != out);
                let a = unsafe { self.col_in(a).as_ptr().add(w0) };
                let o = &mut self.col_out(out)[w0..w1];
                for (i, oi) in o.iter_mut().enumerate() {
                    *oi = unsafe { *a.add(i) };
                }
            }
            Instr::Set { out, bit } => {
                self.col_out(out)[w0..w1].fill(if bit { u64::MAX } else { 0 });
            }
        }
    }

    /// Execute a whole program, cache-blocked over row words.
    ///
    /// §Perf: for tall crossbars the working set of a program (width ×
    /// rows/8 bytes) exceeds cache; running the *whole program* on one
    /// block of rows before advancing keeps every touched column word
    /// resident (all gate ops are row-local, so blocking is semantics-
    /// preserving). Block size targets ~`BLOCK_BYTES` of live columns.
    pub fn execute(&mut self, prog: &Program) {
        assert!(
            prog.width() as usize <= self.cols,
            "program needs {} columns, crossbar has {}",
            prog.width(),
            self.cols
        );
        const BLOCK_BYTES: usize = 256 * 1024; // ~L2-resident working set
        let width = (prog.width() as usize).max(1);
        let wpb = (BLOCK_BYTES / (8 * width)).max(8);
        if self.wpc <= wpb {
            for &instr in prog.instrs() {
                self.step_full(instr);
            }
        } else {
            let mut w0 = 0;
            while w0 < self.wpc {
                let w1 = (w0 + wpb).min(self.wpc);
                for &instr in prog.instrs() {
                    self.step_range(instr, w0, w1);
                }
                w0 = w1;
            }
        }
        self.row_gates += prog.gates() * self.rows as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::gates::GateSet;
    use crate::util::rng::Rng;

    #[test]
    fn bit_roundtrip() {
        let mut x = Crossbar::new(100, 8);
        x.set(63, 3, true);
        x.set(64, 3, true);
        assert!(x.get(63, 3));
        assert!(x.get(64, 3));
        assert!(!x.get(65, 3));
    }

    #[test]
    fn value_roundtrip() {
        let mut x = Crossbar::new(4, 70);
        x.write_value(2, 1, 64, 0xDEADBEEFCAFEF00D);
        assert_eq!(x.read_value(2, 1, 64), 0xDEADBEEFCAFEF00D);
    }

    #[test]
    fn field_roundtrip_matches_scalar_path() {
        let mut rng = Rng::new(1);
        let n = 150; // not a multiple of 64
        let vals = rng.vec_bits(n, 32);
        let mut x = Crossbar::new(n, 40);
        x.write_field(5, 32, &vals);
        // Bulk read agrees.
        assert_eq!(x.read_field(5, 32, n), vals);
        // Scalar read agrees.
        for (r, &v) in vals.iter().enumerate() {
            assert_eq!(x.read_value(r, 5, 32), v);
        }
    }

    #[test]
    fn nor_semantics_all_rows() {
        let mut x = Crossbar::new(128, 4);
        // col0 = pattern, col1 = other pattern.
        for r in 0..128 {
            x.set(r, 0, r % 2 == 0);
            x.set(r, 1, r % 3 == 0);
        }
        x.step(Instr::Nor2 { a: 0, b: 1, out: 2 });
        for r in 0..128 {
            let expect = !((r % 2 == 0) | (r % 3 == 0));
            assert_eq!(x.get(r, 2), expect, "row {r}");
        }
        assert_eq!(x.row_gates(), 128);
    }

    #[test]
    fn maj_semantics() {
        let mut x = Crossbar::new(8, 5);
        for r in 0..8 {
            x.set(r, 0, r & 1 != 0);
            x.set(r, 1, r & 2 != 0);
            x.set(r, 2, r & 4 != 0);
        }
        x.step(Instr::Maj3 { a: 0, b: 1, c: 2, out: 3 });
        for r in 0..8u32 {
            let expect = (r & 1).count_ones() + ((r >> 1) & 1) + ((r >> 2) & 1) >= 2;
            assert_eq!(x.get(r as usize, 3), expect, "row {r}");
        }
    }

    #[test]
    fn set_and_copy() {
        let mut x = Crossbar::new(70, 3);
        x.step(Instr::Set { out: 0, bit: true });
        assert!(x.get(69, 0));
        x.step(Instr::Copy { a: 0, out: 2 });
        assert!(x.get(69, 2));
        x.step(Instr::Set { out: 0, bit: false });
        assert!(!x.get(0, 0));
        assert!(x.get(0, 2));
    }

    #[test]
    fn execute_counts_width() {
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Set { out: 0, bit: false });
        p.push(Instr::Not { a: 0, out: 1 });
        let mut x = Crossbar::new(64, 2);
        x.execute(&p);
        assert!(x.get(13, 1));
    }

    #[test]
    #[should_panic]
    fn execute_rejects_narrow_crossbar() {
        let mut p = Program::new(GateSet::MemristiveNor);
        p.push(Instr::Not { a: 0, out: 10 });
        let mut x = Crossbar::new(64, 4);
        x.execute(&p);
    }
}
