//! The paper's §6 future-work workload: LLM attention decode — a
//! memory-bound matrix-vector workload with *no reuse*, where digital PIM
//! finally wins. Compares tokens/s across the four systems for growing
//! context lengths, and measures the real attention-decode artifact
//! through PJRT.
//!
//! Run with: `cargo run --release --example attention_decode`

use convpim::gpumodel::{GpuDtype, GpuSpec, Roofline};
use convpim::pim::arch::PimArch;
use convpim::pim::gates::GateSet;
use convpim::pim::matpim::{scalar_costs, NumFmt};
use convpim::pim::softfloat::Format;
use convpim::runtime::Engine;
use convpim::util::table::Table;
use convpim::workloads::attention::{decode_workload, DecodeConfig};

fn main() -> anyhow::Result<()> {
    let gpu = Roofline::new(GpuSpec::a6000());
    let arch = PimArch::paper(GateSet::MemristiveNor);
    let fmt = NumFmt::Float(Format::FP32);
    let c = scalar_costs(fmt, GateSet::MemristiveNor);
    let mac_cycles = (c.mul_cycles + c.add_cycles) as f64;

    println!("=== LLM decode (llama-7b-class, fp32): tokens/s per system ===\n");
    let mut t = Table::new(&[
        "context",
        "GMACs/token",
        "reuse FLOP/B",
        "gpu exp tok/s",
        "gpu theo tok/s",
        "PIM tok/s",
        "PIM wins exp GPU?",
    ]);
    for seq in [256u64, 1024, 4096, 16384] {
        let w = decode_workload(DecodeConfig::llama7b(seq));
        let exp = gpu.workload_flops(&w.roofline_layers(), GpuDtype::F32) / w.total_flops();
        let theo = gpu.peak(GpuDtype::F32) / w.total_flops();
        // PIM: weights/KV live in memory; every MAC is a vectored op at
        // full row parallelism (same upper-bound model as the CNNs).
        let pim = arch.total_rows() as f64 * arch.clock_hz / (w.total_macs() * mac_cycles);
        t.row(vec![
            seq.to_string(),
            format!("{:.2}", w.total_macs() / 1e9),
            format!("{:.2}", w.reuse()),
            format!("{exp:.0}"),
            format!("{theo:.0}"),
            format!("{pim:.0}"),
            (pim > exp).to_string(),
        ]);
    }
    println!("{}", t.text());
    println!(
        "the paper's Figure 8 point: decode reuse (~0.5 FLOP/byte) pins the GPU to its memory\n\
         roofline (~{:.1}% of peak), so even high-CC fp32 PIM arithmetic competes.\n",
        100.0 / gpu.ridge_oi(GpuDtype::F32) * 0.5
    );

    match Engine::new() {
        Ok(mut engine) => {
            let exe = engine.load("attention_decode")?;
            let inputs = exe.synth_inputs(3);
            let run = exe.timed(&inputs, 5)?;
            // 16 heads × 2048 cache × 64 dim × 2 matvecs × 2 FLOPs.
            let flops = 16.0 * 2048.0 * 64.0 * 4.0;
            println!(
                "measured attention-decode artifact on XLA-CPU: {:.2} ms/token ({:.2} GFLOP/s — memory-bound here too)",
                run.median_secs() * 1e3,
                flops / run.median_secs() / 1e9
            );
        }
        Err(e) => println!("(measured path skipped: {e:#})"),
    }
    Ok(())
}
