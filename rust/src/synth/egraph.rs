//! A hand-rolled e-graph over the boolean gate IR.
//!
//! The offline registry has no `egg`, so this is the classic
//! hashcons + union-find construction (the same shape
//! `mikeurbach/egg-netlist-synthesizer` and lime's
//! `crates/generic/src/egraph/` build on): every [`Node`] is stored once
//! under its canonical form, [`EGraph::union`] merges equivalence
//! classes, and [`EGraph::rebuild`] restores congruence closure
//! (`f(a) ≡ f(b)` whenever `a ≡ b`) after a batch of unions. Everything
//! iterates in node-insertion order and unions pick the *smaller* class
//! id as representative, so saturation and extraction are deterministic
//! across runs — a requirement, because extracted programs feed cycle
//! counts into cached/golden-pinned reports.

use std::collections::{BTreeMap, HashMap};

use crate::pim::isa::Col;

/// An e-class id (also the id of the node that created the class).
pub type Id = u32;

/// One boolean operator node over e-class operands.
///
/// The operator set mirrors [`crate::pim::isa::Instr`]'s *logic* subset —
/// `Copy` is identity (it never enters the graph) and `Set` becomes
/// [`Node::Const`]. Commutative operands are kept sorted so equal terms
/// hashcons to one node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Node {
    /// A constant column (`Set`).
    Const(bool),
    /// The initial value of input column `c` (read before any write).
    Var(Col),
    /// `!a`.
    Not(Id),
    /// `!(a | b)` — memristive MAGIC NOR.
    Nor2([Id; 2]),
    /// `!(a | b | c)` — memristive three-input NOR.
    Nor3([Id; 3]),
    /// `maj(a, b, c)` — in-DRAM triple-row-activation majority.
    Maj3([Id; 3]),
}

impl Node {
    /// Operand classes, in stored order.
    pub fn children(&self) -> &[Id] {
        match self {
            Node::Const(_) | Node::Var(_) => &[],
            Node::Not(a) => std::slice::from_ref(a),
            Node::Nor2(c) => c,
            Node::Nor3(c) | Node::Maj3(c) => c,
        }
    }

    /// True for leaf nodes (no operands).
    pub fn is_leaf(&self) -> bool {
        matches!(self, Node::Const(_) | Node::Var(_))
    }
}

/// The e-graph: nodes hashconsed under canonical form + a union-find over
/// class ids.
#[derive(Clone, Debug, Default)]
pub struct EGraph {
    /// Node `i` created class `i`; `nodes[i]` is kept canonical by
    /// [`EGraph::rebuild`].
    nodes: Vec<Node>,
    /// Union-find parent pointers over class ids.
    uf: Vec<Id>,
    /// Canonical node → class id.
    memo: HashMap<Node, Id>,
}

impl EGraph {
    pub fn new() -> EGraph {
        EGraph::default()
    }

    /// Number of nodes ever added (classes ≤ nodes).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Number of live (representative) classes.
    pub fn class_count(&self) -> usize {
        (0..self.uf.len() as Id).filter(|&i| self.uf[i as usize] == i).count()
    }

    /// The node that created slot `id` (canonical after a rebuild).
    pub fn node(&self, id: Id) -> Node {
        self.nodes[id as usize]
    }

    /// Representative of `id`'s class (path-halving walk).
    pub fn find(&self, mut id: Id) -> Id {
        while self.uf[id as usize] != id {
            id = self.uf[id as usize];
        }
        id
    }

    fn find_compress(&mut self, mut id: Id) -> Id {
        while self.uf[id as usize] != id {
            let gp = self.uf[self.uf[id as usize] as usize];
            self.uf[id as usize] = gp;
            id = gp;
        }
        id
    }

    /// The canonical form of a node under the current union-find: children
    /// replaced by representatives, commutative operands sorted.
    pub fn canonical(&self, node: Node) -> Node {
        match node {
            Node::Const(_) | Node::Var(_) => node,
            Node::Not(a) => Node::Not(self.find(a)),
            Node::Nor2(mut c) => {
                for x in &mut c {
                    *x = self.find(*x);
                }
                c.sort_unstable();
                Node::Nor2(c)
            }
            Node::Nor3(mut c) => {
                for x in &mut c {
                    *x = self.find(*x);
                }
                c.sort_unstable();
                Node::Nor3(c)
            }
            Node::Maj3(mut c) => {
                for x in &mut c {
                    *x = self.find(*x);
                }
                c.sort_unstable();
                Node::Maj3(c)
            }
        }
    }

    /// Insert a node (hashconsed); returns its class representative.
    pub fn add(&mut self, node: Node) -> Id {
        let node = self.canonical(node);
        if let Some(&id) = self.memo.get(&node) {
            return self.find_compress(id);
        }
        let id = self.nodes.len() as Id;
        assert!(id < Id::MAX, "e-graph exceeded {} nodes", Id::MAX);
        self.nodes.push(node);
        self.uf.push(id);
        self.memo.insert(node, id);
        id
    }

    /// Merge two classes. Returns true if they were distinct. The smaller
    /// id becomes the representative (deterministic across runs).
    pub fn union(&mut self, a: Id, b: Id) -> bool {
        let (ra, rb) = (self.find_compress(a), self.find_compress(b));
        if ra == rb {
            return false;
        }
        let (keep, merge) = if ra < rb { (ra, rb) } else { (rb, ra) };
        self.uf[merge as usize] = keep;
        true
    }

    /// Restore congruence closure after a batch of unions: re-canonicalize
    /// every node and union classes whose nodes collide, to fixpoint.
    pub fn rebuild(&mut self) {
        loop {
            let mut changed = false;
            self.memo.clear();
            for i in 0..self.nodes.len() {
                let canon = {
                    let n = self.nodes[i];
                    self.canonical(n)
                };
                self.nodes[i] = canon;
                let class = self.find_compress(i as Id);
                match self.memo.get(&canon) {
                    Some(&prev) => {
                        let prev = self.find_compress(prev);
                        if prev != class {
                            self.union(prev, class);
                            changed = true;
                        }
                        // Keep the memo entry pointing at the (new) root.
                        let root = self.find_compress(prev);
                        self.memo.insert(canon, root);
                    }
                    None => {
                        self.memo.insert(canon, class);
                    }
                }
            }
            if !changed {
                break;
            }
        }
    }

    /// Per-class node lists under the current (rebuilt) union-find, each
    /// canonical and deduplicated, keyed and ordered by representative id.
    pub fn class_index(&self) -> ClassIndex {
        let mut map: BTreeMap<Id, Vec<Node>> = BTreeMap::new();
        for i in 0..self.nodes.len() {
            let root = self.find(i as Id);
            let canon = self.canonical(self.nodes[i]);
            let entry = map.entry(root).or_default();
            if !entry.contains(&canon) {
                entry.push(canon);
            }
        }
        ClassIndex { map }
    }
}

/// A per-class view of the graph, built once per saturation iteration.
#[derive(Clone, Debug)]
pub struct ClassIndex {
    map: BTreeMap<Id, Vec<Node>>,
}

impl ClassIndex {
    /// The canonical nodes of class `root` (root must be a representative).
    pub fn nodes(&self, root: Id) -> &[Node] {
        self.map.get(&root).map_or(&[], |v| v.as_slice())
    }

    /// The constant value of a class, if it contains one.
    pub fn const_of(&self, root: Id) -> Option<bool> {
        self.nodes(root).iter().find_map(|n| match n {
            Node::Const(b) => Some(*b),
            _ => None,
        })
    }

    /// Classes whose negation lives in class `root`: every `y` with
    /// `Not(y) ∈ root`.
    pub fn negated_in(&self, root: Id) -> impl Iterator<Item = Id> + '_ {
        self.nodes(root).iter().filter_map(|n| match n {
            Node::Not(y) => Some(*y),
            _ => None,
        })
    }

    /// `Nor2` operand pairs stored in class `root`.
    pub fn nor2s_in(&self, root: Id) -> impl Iterator<Item = [Id; 2]> + '_ {
        self.nodes(root).iter().filter_map(|n| match n {
            Node::Nor2(c) => Some(*c),
            _ => None,
        })
    }

    /// Iterate (representative, nodes) in id order.
    pub fn iter(&self) -> impl Iterator<Item = (Id, &[Node])> {
        self.map.iter().map(|(&k, v)| (k, v.as_slice()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hashcons_dedupes_and_sorts_commutative() {
        let mut g = EGraph::new();
        let a = g.add(Node::Var(0));
        let b = g.add(Node::Var(1));
        let n1 = g.add(Node::Nor2([a, b]));
        let n2 = g.add(Node::Nor2([b, a]));
        assert_eq!(n1, n2);
        assert_eq!(g.len(), 3);
        assert_eq!(g.add(Node::Var(0)), a);
    }

    #[test]
    fn union_prefers_smaller_id_and_rebuild_closes_congruence() {
        let mut g = EGraph::new();
        let a = g.add(Node::Var(0));
        let b = g.add(Node::Var(1));
        let fa = g.add(Node::Not(a));
        let fb = g.add(Node::Not(b));
        assert_ne!(g.find(fa), g.find(fb));
        assert!(g.union(a, b));
        g.rebuild();
        // a ≡ b forces Not(a) ≡ Not(b).
        assert_eq!(g.find(fa), g.find(fb));
        assert_eq!(g.find(b), a, "smaller id is the representative");
        assert!(!g.union(fa, fb), "already merged");
    }

    #[test]
    fn class_index_exposes_consts_and_negations() {
        let mut g = EGraph::new();
        let a = g.add(Node::Var(0));
        let t = g.add(Node::Const(true));
        let na = g.add(Node::Not(a));
        g.union(na, t); // pretend !a ≡ 1
        g.rebuild();
        let idx = g.class_index();
        let root = g.find(na);
        assert_eq!(idx.const_of(root), Some(true));
        assert_eq!(idx.negated_in(root).collect::<Vec<_>>(), vec![g.find(a)]);
        assert_eq!(idx.const_of(g.find(a)), None);
    }
}
