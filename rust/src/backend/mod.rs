//! First-class evaluation backends: one `evaluate` API over every
//! hardware model in the repo.
//!
//! The paper's whole contribution is a *comparison* — digital PIM vs GPU
//! across workloads — and real-PIM benchmarking efforts (Gómez-Luna et
//! al. 2021; Ghose et al. 2019) organize exactly this kind of study as a
//! *workload × platform* matrix. This module promotes the platform to a
//! first-class value:
//!
//! * [`Backend`] — the platform trait: `id()`, `describe()`,
//!   `supports(&WorkloadSpec)`, and
//!   `evaluate(&WorkloadSpec, NumFmt) -> Estimate`;
//! * [`Estimate`] — the flat result record every backend produces:
//!   throughput in the workload's unit, throughput/W, the normalization
//!   power, compute complexity and bytes-moved where defined, and
//!   backend-specific notes as JSON;
//! * [`AnalyticPim`] — the paper's architecture-scale digital-PIM model
//!   ([`crate::pim::arch::PimArch`] + compiled microcode costs, including
//!   the [`crate::pim::matpim::CnnPimModel`] /
//!   [`crate::pim::matpim::MatmulModel`] schedule paths);
//! * [`ExecutedCrossbar`] — *executed* evaluation on the bit-exact
//!   crossbar simulator ([`crate::pim::conv`]): deterministic seeded
//!   operands, measured cycles/gates, enforced agreement with the
//!   analytic model and bit-exactness against a host reference;
//! * [`ExecutedNet`] — *executed* full-network inference
//!   ([`crate::pim::netexec`]): a whole conv/pool/relu/fc layer graph
//!   run end to end with pipelined tiles, per-layer analytic
//!   cross-validation, and inter-layer data movement reported as its
//!   own cost bucket;
//! * [`OptimizedPim`] — the same analytic model over the
//!   equality-saturation synthesizer's microcode ([`crate::synth`]):
//!   `pim-opt:*` vs `pim:*` in one `compare` quantifies how much the
//!   hand-derived microcode leaves on the table;
//! * [`GpuRoofline`] — the datasheet × roofline GPU baselines
//!   (experimental memory-bound / theoretical compute peak) over
//!   [`crate::gpumodel`];
//! * [`parse`] — the string-keyed registry
//!   (`pim:memristive`, `pim-exec:dram`, `gpu:a6000:experimental:fp32`,
//!   …) behind `convpim compare --backends` and the campaign `backends`
//!   axis.
//!
//! The pre-existing evaluation paths — [`crate::metrics::cc_point`] and
//! [`crate::sweep::SweepPoint::eval`] — are thin adapters over these
//! backends: they compute the **same floating-point expressions in the
//! same order**, so their outputs are byte-identical to the pre-backend
//! code (pinned by `tests/service_equivalence.rs`, the golden snapshots,
//! and `tests/backend_parity.rs`).
//!
//! ```
//! use convpim::backend::{self, Backend as _};
//! use convpim::pim::matpim::NumFmt;
//! use convpim::sweep::WorkloadSpec;
//!
//! let pim = backend::parse("pim:memristive").unwrap();
//! let gpu = backend::parse("gpu:a6000:experimental").unwrap();
//! let w = WorkloadSpec::from_name("cnn-alexnet").unwrap();
//! let fmt = NumFmt::Float(convpim::pim::softfloat::Format::FP32);
//! let p = pim.evaluate(&w, fmt).unwrap();
//! let g = gpu.evaluate(&w, fmt).unwrap();
//! assert_eq!(p.unit, "img/s");
//! assert!(p.throughput > 0.0 && g.throughput > 0.0);
//! ```

pub mod analytic;
pub mod executed;
pub mod gpu;
pub mod optimized;

use anyhow::Result;

pub use analytic::AnalyticPim;
pub use executed::{ExecutedCrossbar, ExecutedNet, CONV_EXEC_SEED};
pub use gpu::GpuRoofline;
pub use optimized::OptimizedPim;

use crate::gpumodel::{GpuDtype, GpuSpec};
use crate::pim::gates::GateSet;
use crate::pim::matpim::NumFmt;
use crate::sweep::campaign::{ArchSpec, CnnModel, GpuMode, WorkloadSpec};
use crate::util::json::Json;
use crate::workloads::{ConvSpec, LayerCost};

/// One evaluation platform: a hardware model that can judge workloads.
///
/// Implementations are cheap to construct and hold no mutable state —
/// `evaluate` is a pure function of `(workload, fmt)` (the executed
/// backend uses a fixed operand seed, [`CONV_EXEC_SEED`], precisely so
/// this holds), which is what lets backend results share the
/// content-addressed result cache.
pub trait Backend: Send + Sync {
    /// Canonical registry id (parseable by [`parse`], e.g.
    /// `pim:memristive`, `gpu:a6000:experimental`).
    fn id(&self) -> String;

    /// One-line human description (shown by `convpim list`).
    fn describe(&self) -> String;

    /// Whether [`Backend::evaluate`] can judge this workload.
    fn supports(&self, workload: &WorkloadSpec) -> bool;

    /// Evaluate a workload at a number format into an [`Estimate`].
    fn evaluate(&self, workload: &WorkloadSpec, fmt: NumFmt) -> Result<Estimate>;
}

/// The flat result record of one `(backend, workload, format)` cell.
#[derive(Clone, Debug, PartialEq)]
pub struct Estimate {
    /// The producing backend's canonical id.
    pub backend: String,
    /// Workload name ([`WorkloadSpec::name`]).
    pub workload: String,
    /// Number-format name (`fixed32`, `fp16`, …).
    pub format: String,
    /// Unit of `throughput` (`ops/s`, `matmul/s`, `img/s`, `tok/s`,
    /// `mac/s` — [`WorkloadSpec::unit`]).
    pub unit: String,
    /// Throughput in `unit`.
    pub throughput: f64,
    /// Throughput per watt (the paper's energy-efficiency metric, using
    /// the max-power normalization of §2.2).
    pub per_watt: f64,
    /// The normalization power in watts (`throughput / per_watt`).
    pub power_w: f64,
    /// Compute complexity in gates/bit, where defined (elementwise
    /// arithmetic on PIM backends).
    pub cc: Option<f64>,
    /// Bytes moved per `unit` of work on this platform, where the model
    /// tracks it (GPU rooflines; PIM computes in place and charges
    /// movement only in the executed backend's notes).
    pub bytes_per_unit: Option<f64>,
    /// Backend-specific details (compiled program costs, executed
    /// measured-vs-analytic records, roofline inputs).
    pub notes: Json,
}

impl Estimate {
    /// Machine-readable record (one cell of a `compare` response).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("backend", Json::s(self.backend.clone())),
            ("workload", Json::s(self.workload.clone())),
            ("format", Json::s(self.format.clone())),
            ("unit", Json::s(self.unit.clone())),
            ("throughput", Json::n(self.throughput)),
            ("per_watt", Json::n(self.per_watt)),
            ("power_w", Json::n(self.power_w)),
            ("cc", self.cc.map(Json::n).unwrap_or(Json::Null)),
            (
                "bytes_per_unit",
                self.bytes_per_unit.map(Json::n).unwrap_or(Json::Null),
            ),
            ("notes", self.notes.clone()),
        ])
    }
}

/// The grammar `parse` accepts (also the error-message help text).
pub const ID_GRAMMAR: &str = "pim:SET[@RxC] | pim-opt:SET[@RxC] | pim-exec:SET[@RxC] | \
     pim-exec-net:SET[@RxC] | gpu:NAME[:MODE[:DTYPE]] \
     (SET: memristive|dram or a registered archdef name — ambit|simdram|imply|plim|felix|nor|…, \
     see `convpim arch`; NAME: a6000|a100|v100|rtx3090; \
     MODE: experimental|theoretical; DTYPE: auto|fp32|fp16|fp16-tensor)";

/// Parse a backend id into a backend instance.
///
/// Ids are case-sensitive except the GPU name. Omitted GPU fields take
/// defaults (`experimental` mode, `auto` dtype — derived from the
/// workload and format the way the sweep engine always has). The
/// returned backend's [`Backend::id`] is the *canonical* spelling
/// (defaults made explicit), so distinct spellings of one platform
/// canonicalize to one cache identity wherever ids are canonicalized
/// before caching (the campaign `backends` axis does this).
pub fn parse(id: &str) -> Result<Box<dyn Backend>> {
    let (kind, rest) = id.split_once(':').ok_or_else(|| {
        anyhow::anyhow!("backend id `{id}` needs a `kind:...` form; known: {ID_GRAMMAR}")
    })?;
    match kind {
        "pim" => Ok(Box::new(AnalyticPim::new(parse_arch(rest)?))),
        "pim-opt" => Ok(Box::new(OptimizedPim::new(parse_arch(rest)?))),
        "pim-exec" => Ok(Box::new(ExecutedCrossbar::new(parse_arch(rest)?))),
        "pim-exec-net" => Ok(Box::new(ExecutedNet::new(parse_arch(rest)?))),
        "gpu" => parse_gpu(rest),
        other => anyhow::bail!("unknown backend kind `{other}`; known: {ID_GRAMMAR}"),
    }
}

/// Parse the `SET[@RxC]` architecture part of a PIM backend id.
fn parse_arch(s: &str) -> Result<ArchSpec> {
    let (set_name, dims) = match s.split_once('@') {
        None => (s, None),
        Some((n, d)) => (n, Some(d)),
    };
    let set = crate::archdef::lookup(set_name).ok_or_else(|| {
        anyhow::anyhow!(
            "backend gate set must be a registered architecture ({}), got `{set_name}`",
            crate::archdef::names().join("|")
        )
    })?;
    match dims {
        None => Ok(ArchSpec::paper(set)),
        Some(d) => {
            let (r, c) = d.split_once('x').ok_or_else(|| {
                anyhow::anyhow!("backend crossbar dims must be `ROWSxCOLS`, got `@{d}`")
            })?;
            let parse_dim = |v: &str| -> Result<u64> {
                v.parse().map_err(|_| {
                    anyhow::anyhow!("backend crossbar dims must be `ROWSxCOLS`, got `@{d}`")
                })
            };
            let (r, c) = (parse_dim(r)?, parse_dim(c)?);
            anyhow::ensure!(r > 0 && c > 0, "backend crossbar dims must be positive (got {r}x{c})");
            Ok(ArchSpec::with_dims(set, r, c))
        }
    }
}

/// Parse the `NAME[:MODE[:DTYPE]]` part of a GPU backend id.
fn parse_gpu(rest: &str) -> Result<Box<dyn Backend>> {
    let mut parts = rest.split(':');
    let name = parts.next().unwrap_or("");
    let spec = GpuSpec::by_name(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown gpu `{name}`; available: {}",
            GpuSpec::all()
                .iter()
                .map(|s| s.name.to_ascii_lowercase())
                .collect::<Vec<_>>()
                .join(", ")
        )
    })?;
    let mode = match parts.next() {
        None | Some("experimental") | Some("exp") => GpuMode::Experimental,
        Some("theoretical") | Some("theo") => GpuMode::Theoretical,
        Some(other) => anyhow::bail!(
            "gpu backend mode must be `experimental` or `theoretical`, got `{other}`"
        ),
    };
    let dtype = match parts.next() {
        None | Some("auto") => None,
        Some("fp32") => Some(GpuDtype::F32),
        Some("fp16") => Some(GpuDtype::F16),
        Some("fp16-tensor") => Some(GpuDtype::F16Tensor),
        Some(other) => anyhow::bail!(
            "gpu backend dtype must be auto|fp32|fp16|fp16-tensor, got `{other}`"
        ),
    };
    if let Some(extra) = parts.next() {
        anyhow::bail!("trailing backend id segment `:{extra}`; grammar: {ID_GRAMMAR}");
    }
    Ok(Box::new(GpuRoofline::new(spec, mode, dtype)))
}

/// Resolve a `conv-exec` workload's layer: bounds-check the 1-based
/// `conv` index against the model's executable conv layers and return
/// the full layer cost (the GPU baseline charges the full layer) plus
/// the down-scaled executable spec (what the PIM backends predict /
/// execute). One shared lookup so the three backends cannot drift on
/// the bounds rule or error text.
pub(crate) fn conv_exec_layer(
    model: CnnModel,
    conv: u32,
    scale: u32,
) -> Result<(LayerCost, ConvSpec)> {
    let w = model.workload();
    let convs = w.conv_layers();
    anyhow::ensure!(
        conv >= 1 && (conv as usize) <= convs.len(),
        "{} has {} executable conv layers; `conv` index {conv} is out of range",
        w.name,
        convs.len()
    );
    let (layer, full) = convs[conv as usize - 1];
    Ok((layer.clone(), full.scaled(scale)))
}

/// Parse a JSON array of backend-id strings; `ctx` names the owning
/// document for error messages. With `canonicalize`, every id is
/// resolved through the registry and replaced by its canonical spelling
/// (defaults made explicit) — the campaign `backends` axis does this so
/// two spellings of one platform share cache entries; wire surfaces
/// that echo the request verbatim keep the raw spelling.
pub(crate) fn ids_from_json(v: &Json, ctx: &str, canonicalize: bool) -> Result<Vec<String>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("{ctx} `backends` must be an array of backend ids"))?
        .iter()
        .map(|b| {
            let id = b
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("{ctx} `backends` entries must be strings"))?;
            if canonicalize {
                Ok(parse(id)?.id())
            } else {
                Ok(id.to_string())
            }
        })
        .collect()
}

/// The default backend inventory (`convpim list`): the paper's two PIM
/// technologies plus every registered architecture definition — each in
/// all four PIM evaluation kinds at its native dimensions — and every
/// GPU in the datasheet database in both roofline modes.
pub fn builtin() -> Vec<Box<dyn Backend>> {
    // Legacy pair first (their ids predate the DSL and lead the listing),
    // then the archdef catalogue; `lookup` maps the legacy names to the
    // legacy variants, so the registry yields no duplicates.
    let names = crate::archdef::names();
    let sets: Vec<GateSet> = GateSet::all()
        .into_iter()
        .chain(names.iter().filter_map(|n| match crate::archdef::lookup(n) {
            Some(set @ GateSet::Arch(_)) => Some(set),
            _ => None,
        }))
        .collect();
    let mut out: Vec<Box<dyn Backend>> = Vec::new();
    for &set in &sets {
        out.push(Box::new(AnalyticPim::new(ArchSpec::paper(set))));
    }
    for &set in &sets {
        out.push(Box::new(OptimizedPim::new(ArchSpec::paper(set))));
    }
    for &set in &sets {
        out.push(Box::new(ExecutedCrossbar::new(ArchSpec::paper(set))));
    }
    for &set in &sets {
        out.push(Box::new(ExecutedNet::new(ArchSpec::paper(set))));
    }
    for spec in GpuSpec::all() {
        for mode in [GpuMode::Experimental, GpuMode::Theoretical] {
            out.push(Box::new(GpuRoofline::new(spec, mode, None)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_canonicalizes_and_round_trips() {
        // Canonical ids parse back to themselves.
        for id in [
            "pim:memristive",
            "pim:dram",
            "pim:memristive@1024x512",
            "pim-opt:memristive",
            "pim-opt:dram@512x1024",
            "pim-exec:dram",
            "pim-exec-net:memristive",
            "pim-exec-net:dram@512x1024",
            "pim:ambit",
            "pim:nor",
            "pim:imply@512x1024",
            "pim-opt:felix",
            "pim-exec:simdram",
            "pim-exec-net:plim",
            "gpu:a6000:experimental",
            "gpu:a100:theoretical",
            "gpu:v100:experimental:fp16",
            "gpu:rtx3090:theoretical:fp16-tensor",
        ] {
            let b = parse(id).unwrap_or_else(|e| panic!("{id}: {e:#}"));
            assert_eq!(b.id(), id, "canonical ids are fixed points");
            assert_eq!(parse(&b.id()).unwrap().id(), b.id());
        }
        // Defaults are made explicit in the canonical id.
        assert_eq!(parse("gpu:a6000").unwrap().id(), "gpu:a6000:experimental");
        assert_eq!(parse("gpu:A6000:exp").unwrap().id(), "gpu:a6000:experimental");
        assert_eq!(parse("gpu:a100:theo").unwrap().id(), "gpu:a100:theoretical");
        assert_eq!(
            parse("gpu:a6000:experimental:auto").unwrap().id(),
            "gpu:a6000:experimental"
        );
    }

    #[test]
    fn parse_rejects_malformed_ids() {
        for bad in [
            "pim",
            "pim:cmos",
            "pim-opt:cmos",
            "pim-opt:memristive@0x0",
            "pim:memristive@8",
            "pim:memristive@0x1024",
            "pim:memristive@8xbig",
            "pim-exec:analog",
            "pim-exec-net:cmos",
            "gpu:h100",
            "gpu:a6000:overclocked",
            "gpu:a6000:experimental:int8",
            "gpu:a6000:experimental:fp32:extra",
            "tpu:v4",
            "",
        ] {
            assert!(parse(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn builtin_inventory_is_parseable_and_described() {
        let inventory = builtin();
        // 4 PIM kinds × (2 legacy + ≥6 archdef) + 4 GPUs × 2 modes.
        assert!(inventory.len() >= 40, "inventory has {} backends", inventory.len());
        for b in &inventory {
            assert_eq!(parse(&b.id()).unwrap().id(), b.id(), "{}", b.id());
            assert!(!b.describe().is_empty(), "{}", b.id());
        }
        // No duplicate ids.
        let mut ids: Vec<String> = inventory.iter().map(|b| b.id()).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate backend ids in the inventory");
    }

    #[test]
    fn estimate_json_carries_every_field() {
        let b = parse("pim:memristive").unwrap();
        let w = WorkloadSpec::from_name("elementwise-add").unwrap();
        let e = b.evaluate(&w, NumFmt::Fixed(32)).unwrap();
        let j = e.to_json();
        assert_eq!(j.get("backend").unwrap().as_str(), Some("pim:memristive"));
        assert_eq!(j.get("unit").unwrap().as_str(), Some("ops/s"));
        assert!(j.get("throughput").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("cc").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("notes").unwrap().get("gates").is_some());
    }
}
