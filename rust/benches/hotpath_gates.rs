//! Hot-path microbench: the crossbar column-gate engine (the simulator's
//! inner loop and the §Perf optimization target). Reports simulated
//! row-gates per second across crossbar heights and gate mixes.

use convpim::pim::fixed::{self, FixedOp};
use convpim::pim::float;
use convpim::pim::gates::GateSet;
use convpim::pim::isa::{Instr, Program};
use convpim::pim::softfloat::Format;
use convpim::pim::xbar::Crossbar;
use convpim::util::bench::{bench, header, report, BenchConfig};
use convpim::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    header("hotpath: crossbar column-gate engine");

    // Raw NOR storm: 1024 gates over random columns.
    let mut rng = Rng::new(1);
    for rows in [1024usize, 16384, 262_144] {
        let cols = 64u32;
        let mut prog = Program::new(GateSet::MemristiveNor);
        for _ in 0..1024 {
            let a = rng.below(cols as u64) as u32;
            let mut b = rng.below(cols as u64) as u32;
            let mut o = rng.below(cols as u64) as u32;
            while b == a {
                b = rng.below(cols as u64) as u32;
            }
            while o == a || o == b {
                o = rng.below(cols as u64) as u32;
            }
            prog.push(Instr::Nor2 { a, b, out: o });
        }
        let mut x = Crossbar::new(rows, cols as usize);
        let units = prog.gates() as f64 * rows as f64;
        report(bench(
            &format!("nor2_storm rows={rows}"),
            units,
            &cfg,
            || x.execute(&prog),
        ));
    }

    // Real programs: fixed32 add / fp32 add / fp32 mul.
    for (name, prog) in [
        ("fixed32_add", fixed::program(FixedOp::Add, 32, GateSet::MemristiveNor)),
        ("fp32_add", float::program(FixedOp::Add, Format::FP32, GateSet::MemristiveNor)),
        ("fp32_mul", float::program(FixedOp::Mul, Format::FP32, GateSet::MemristiveNor)),
        ("fixed32_add_dram", fixed::program(FixedOp::Add, 32, GateSet::DramMaj)),
    ] {
        let rows = 65_536;
        let mut x = Crossbar::new(rows, prog.width() as usize);
        let units = prog.gates() as f64 * rows as f64;
        report(bench(&format!("{name} rows={rows}"), units, &cfg, || {
            x.execute(&prog)
        }));
    }
}
