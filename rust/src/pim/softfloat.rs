//! Host-side bit-exact IEEE-754 reference ("softfloat") generic over
//! (exponent, mantissa) widths.
//!
//! This is the oracle the in-memory floating-point microcode
//! ([`crate::pim::float`]) is validated against. Its own correctness is
//! established by exhaustive-style randomized comparison with the native
//! `f32`/`f64` hardware arithmetic (which is IEEE-754 round-to-nearest-even
//! on every platform Rust targets); the generic implementation then serves
//! as the reference for fp16, where no native type exists.
//!
//! Semantics: round-to-nearest-even, full subnormal support, and
//! *canonical* quiet-NaN results (sign 0, mantissa MSB set) — the same
//! convention the gate-level microcode produces, so results compare as
//! exact bit patterns (tests treat any-NaN == any-NaN when comparing
//! against native hardware, which propagates payloads).

/// A binary floating-point format: 1 sign bit, `exp` exponent bits,
/// `man` mantissa bits (total ≤ 64).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Format {
    pub exp: u32,
    pub man: u32,
}

impl Format {
    /// IEEE binary16.
    pub const FP16: Format = Format { exp: 5, man: 10 };
    /// IEEE binary32.
    pub const FP32: Format = Format { exp: 8, man: 23 };
    /// IEEE binary64.
    pub const FP64: Format = Format { exp: 11, man: 52 };

    /// Total bits.
    pub fn bits(self) -> u32 {
        1 + self.exp + self.man
    }

    /// Exponent bias.
    pub fn bias(self) -> i64 {
        (1i64 << (self.exp - 1)) - 1
    }

    /// All-ones exponent field value (Inf/NaN).
    pub fn emax_field(self) -> u64 {
        (1u64 << self.exp) - 1
    }

    fn man_mask(self) -> u64 {
        (1u64 << self.man) - 1
    }

    fn sign_bit(self) -> u64 {
        1u64 << (self.exp + self.man)
    }

    /// Canonical quiet NaN (sign 0, quiet bit set).
    pub fn qnan(self) -> u64 {
        (self.emax_field() << self.man) | (1u64 << (self.man - 1))
    }

    /// ±Infinity.
    pub fn inf(self, sign: bool) -> u64 {
        (sign as u64) * self.sign_bit() | (self.emax_field() << self.man)
    }

    /// ±0.
    pub fn zero(self, sign: bool) -> u64 {
        (sign as u64) * self.sign_bit()
    }

    /// Classification helpers.
    pub fn is_nan(self, x: u64) -> bool {
        (x >> self.man) & self.emax_field() == self.emax_field() && x & self.man_mask() != 0
    }

    pub fn is_inf(self, x: u64) -> bool {
        (x >> self.man) & self.emax_field() == self.emax_field() && x & self.man_mask() == 0
    }

    pub fn is_zero(self, x: u64) -> bool {
        x & !self.sign_bit() == 0
    }

    fn unpack(self, x: u64) -> (bool, u64, u64) {
        let s = x & self.sign_bit() != 0;
        let e = (x >> self.man) & self.emax_field();
        let m = x & self.man_mask();
        (s, e, m)
    }

    /// Effective exponent (subnormals share the minimum exponent) and
    /// significand with the hidden bit applied.
    fn sig(self, e: u64, m: u64) -> (i64, u64) {
        if e == 0 {
            (1, m)
        } else {
            (e as i64, m | (1u64 << self.man))
        }
    }

    /// Convert an `f64` to this format's bits (RNE; used by tests and by
    /// workload generators for fp16).
    pub fn from_f64(self, v: f64) -> u64 {
        let b = v.to_bits();
        if self == Format::FP64 {
            return b;
        }
        if v.is_nan() {
            return self.qnan();
        }
        let s = b >> 63 != 0;
        if v.is_infinite() {
            return self.inf(s);
        }
        if v == 0.0 {
            return self.zero(s);
        }
        let e64 = ((b >> 52) & 0x7FF) as i64;
        let m64 = b & ((1u64 << 52) - 1);
        // value = sig * 2^(eeff - 1023 - 52), sig has hidden at bit 52.
        let (eeff, sig) = if e64 == 0 { (1, m64) } else { (e64, m64 | (1 << 52)) };
        // Convert to target scale: f at man+3 frame.
        let e_t = eeff - 1023 + self.bias();
        // f = sig << 3 in the 52-mantissa frame; round_pack re-normalizes.
        round_pack(self, s, e_t + (self.man as i64 + 3) - (52 + 3), (sig as u128) << 3)
        // note: exponent adjusted so sig's frame (hidden at 52+3 after <<3)
        // maps to the target frame (hidden at man+3).
    }

    /// Convert this format's bits to an `f64` (exact for exp ≤ 11,
    /// man ≤ 52 — true for all supported formats).
    pub fn to_f64(self, x: u64) -> f64 {
        if self == Format::FP64 {
            return f64::from_bits(x);
        }
        let (s, e, m) = self.unpack(x);
        if e == self.emax_field() {
            if m != 0 {
                return f64::NAN;
            }
            return if s { f64::NEG_INFINITY } else { f64::INFINITY };
        }
        if e == 0 && m == 0 {
            return if s { -0.0 } else { 0.0 };
        }
        let (eeff, sig) = self.sig(e, m);
        let mag = sig as f64 * ((eeff - self.bias() - self.man as i64) as f64).exp2();
        if s {
            -mag
        } else {
            mag
        }
    }
}

/// `x >> d` with the sticky (jam) bit ORed into bit 0.
fn shift_right_jam(x: u64, d: i64) -> u64 {
    if d <= 0 {
        return x;
    }
    if d >= 64 {
        return (x != 0) as u64;
    }
    let dropped = x & ((1u64 << d) - 1);
    (x >> d) | (dropped != 0) as u64
}

/// Normalize, denormalize, round (RNE), and pack.
///
/// Input value is `(-1)^s × f × 2^(e - bias - man - 3)`, i.e. `f` carries
/// the significand with 3 guard bits below the ULP and a jammed sticky in
/// bit 0. `f` must be nonzero.
fn round_pack(fmt: Format, s: bool, mut e: i64, mut f: u128) -> u64 {
    debug_assert!(f != 0);
    let target = (fmt.man + 3) as i64;
    let msb = 127 - f.leading_zeros() as i64;
    if msb > target {
        let d = msb - target;
        let dropped = f & ((1u128 << d) - 1);
        f = (f >> d) | (dropped != 0) as u128;
        e += d;
    } else if msb < target {
        let d = target - msb;
        f <<= d;
        e -= d;
    }
    // Subnormal: shift down so the result packs with exponent field 0.
    if e <= 0 {
        let d = 1 - e;
        if d >= 127 {
            f = 1; // pure sticky
        } else {
            let dropped = f & ((1u128 << d) - 1);
            f = (f >> d) | (dropped != 0) as u128;
        }
        e = 1;
    }
    let l = (f >> 3) & 1;
    let g = (f >> 2) & 1;
    let r = (f >> 1) & 1;
    let st = f & 1;
    let round_up = g & (l | r | st);
    let mant = (f >> 3) + round_up;
    // Pack with the carry-rolls-into-exponent trick: subnormal carry
    // becomes the smallest normal; normal mantissa carry increments the
    // exponent; increment past emax-1 becomes Inf below.
    let bits = (((e - 1) as u128) << fmt.man) + mant;
    if (bits >> fmt.man) as u64 >= fmt.emax_field() {
        return fmt.inf(s);
    }
    (s as u64) * fmt.sign_bit() | bits as u64
}

/// IEEE-754 addition.
pub fn add(fmt: Format, a: u64, b: u64) -> u64 {
    let (sa, ea, ma) = fmt.unpack(a);
    let (sb, eb, mb) = fmt.unpack(b);
    if fmt.is_nan(a) || fmt.is_nan(b) {
        return fmt.qnan();
    }
    match (fmt.is_inf(a), fmt.is_inf(b)) {
        (true, true) if sa != sb => return fmt.qnan(),
        (true, _) => return fmt.inf(sa),
        (_, true) => return fmt.inf(sb),
        _ => {}
    }
    if fmt.is_zero(a) && fmt.is_zero(b) {
        return fmt.zero(sa && sb); // -0 + -0 = -0, else +0
    }
    if fmt.is_zero(a) {
        return b;
    }
    if fmt.is_zero(b) {
        return a;
    }
    let (ea, siga) = fmt.sig(ea, ma);
    let (eb, sigb) = fmt.sig(eb, mb);
    // Order so x is the larger magnitude (exponent, then significand).
    let (sx, ex, sigx, sy, ey, sigy) =
        if (ea, siga) >= (eb, sigb) {
            (sa, ea, siga, sb, eb, sigb)
        } else {
            (sb, eb, sigb, sa, ea, siga)
        };
    let mx3 = sigx << 3;
    let my3 = shift_right_jam(sigy << 3, ex - ey);
    if sx == sy {
        round_pack(fmt, sx, ex, (mx3 + my3) as u128)
    } else {
        let f = mx3 - my3;
        if f == 0 {
            return fmt.zero(false); // exact cancellation -> +0 under RNE
        }
        round_pack(fmt, sx, ex, f as u128)
    }
}

/// IEEE-754 subtraction (`a - b` = `a + (-b)`).
pub fn sub(fmt: Format, a: u64, b: u64) -> u64 {
    add(fmt, a, b ^ fmt.sign_bit())
}

/// IEEE-754 multiplication.
pub fn mul(fmt: Format, a: u64, b: u64) -> u64 {
    let (sa, ea, ma) = fmt.unpack(a);
    let (sb, eb, mb) = fmt.unpack(b);
    let s = sa ^ sb;
    if fmt.is_nan(a) || fmt.is_nan(b) {
        return fmt.qnan();
    }
    if fmt.is_inf(a) || fmt.is_inf(b) {
        if fmt.is_zero(a) || fmt.is_zero(b) {
            return fmt.qnan(); // Inf × 0
        }
        return fmt.inf(s);
    }
    if fmt.is_zero(a) || fmt.is_zero(b) {
        return fmt.zero(s);
    }
    let (ea, siga) = fmt.sig(ea, ma);
    let (eb, sigb) = fmt.sig(eb, mb);
    let f = siga as u128 * sigb as u128; // exact, ≤ 2^(2·man+2)
    let e = ea + eb - fmt.bias() + 3 - fmt.man as i64;
    round_pack(fmt, s, e, f)
}

/// IEEE-754 division.
pub fn div(fmt: Format, a: u64, b: u64) -> u64 {
    let (sa, ea, ma) = fmt.unpack(a);
    let (sb, eb, mb) = fmt.unpack(b);
    let s = sa ^ sb;
    if fmt.is_nan(a) || fmt.is_nan(b) {
        return fmt.qnan();
    }
    match (fmt.is_inf(a), fmt.is_inf(b)) {
        (true, true) => return fmt.qnan(),
        (true, false) => return fmt.inf(s),
        (false, true) => return fmt.zero(s),
        _ => {}
    }
    match (fmt.is_zero(a), fmt.is_zero(b)) {
        (true, true) => return fmt.qnan(),
        (false, true) => return fmt.inf(s), // x/0 (IEEE: raises divide-by-zero, value ±Inf)
        (true, false) => return fmt.zero(s),
        _ => {}
    }
    let (ea, siga) = fmt.sig(ea, ma);
    let (eb, sigb) = fmt.sig(eb, mb);
    // Normalize both significands so the hidden position is exact
    // (subnormal inputs have leading zeros).
    let ka = (fmt.man + 1) as i64 - (64 - siga.leading_zeros() as i64);
    let kb = (fmt.man + 1) as i64 - (64 - sigb.leading_zeros() as i64);
    let siga_n = siga << ka;
    let sigb_n = sigb << kb;
    let e = (ea - ka) - (eb - kb) + fmt.bias() - 1;
    let num = (siga_n as u128) << (fmt.man + 4);
    let q = num / sigb_n as u128;
    let rem = num % sigb_n as u128;
    round_pack(fmt, s, e, q | (rem != 0) as u128)
}

/// Dispatch by op name (used by sweeps/benches).
pub fn apply(fmt: Format, op: crate::pim::fixed::FixedOp, a: u64, b: u64) -> u64 {
    use crate::pim::fixed::FixedOp::*;
    match op {
        Add => add(fmt, a, b),
        Sub => sub(fmt, a, b),
        Mul => mul(fmt, a, b),
        Div => div(fmt, a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Compare against native hardware arithmetic, treating any-NaN as
    /// equal to any-NaN (hardware propagates payloads; we canonicalize).
    fn check_f32(op: fn(Format, u64, u64) -> u64, host: fn(f32, f32) -> f32, n: usize, seed: u64) {
        let fmt = Format::FP32;
        let mut rng = Rng::new(seed);
        for i in 0..n {
            let a = rng.float_pattern(8, 23) as u32;
            let b = rng.float_pattern(8, 23) as u32;
            let got = op(fmt, a as u64, b as u64) as u32;
            let expect = host(f32::from_bits(a), f32::from_bits(b)).to_bits();
            let ok = got == expect
                || (fmt.is_nan(got as u64) && f32::from_bits(expect).is_nan());
            assert!(
                ok,
                "i={i} a={a:#010x} b={b:#010x} got={got:#010x} expect={expect:#010x}"
            );
        }
    }

    fn check_f64(op: fn(Format, u64, u64) -> u64, host: fn(f64, f64) -> f64, n: usize, seed: u64) {
        let fmt = Format::FP64;
        let mut rng = Rng::new(seed);
        for i in 0..n {
            let a = rng.float_pattern(11, 52);
            let b = rng.float_pattern(11, 52);
            let got = op(fmt, a, b);
            let expect = host(f64::from_bits(a), f64::from_bits(b)).to_bits();
            let ok = got == expect || (fmt.is_nan(got) && f64::from_bits(expect).is_nan());
            assert!(
                ok,
                "i={i} a={a:#018x} b={b:#018x} got={got:#018x} expect={expect:#018x}"
            );
        }
    }

    #[test]
    fn add_matches_native_f32() {
        check_f32(add, |x, y| x + y, 40_000, 101);
    }

    #[test]
    fn sub_matches_native_f32() {
        check_f32(sub, |x, y| x - y, 40_000, 102);
    }

    #[test]
    fn mul_matches_native_f32() {
        check_f32(mul, |x, y| x * y, 40_000, 103);
    }

    #[test]
    fn div_matches_native_f32() {
        check_f32(div, |x, y| x / y, 40_000, 104);
    }

    #[test]
    fn add_matches_native_f64() {
        check_f64(add, |x, y| x + y, 20_000, 201);
    }

    #[test]
    fn mul_matches_native_f64() {
        check_f64(mul, |x, y| x * y, 20_000, 202);
    }

    #[test]
    fn div_matches_native_f64() {
        check_f64(div, |x, y| x / y, 20_000, 203);
    }

    #[test]
    fn signed_zero_rules() {
        let f = Format::FP32;
        let pz = f.zero(false);
        let nz = f.zero(true);
        assert_eq!(add(f, nz, nz), nz);
        assert_eq!(add(f, pz, nz), pz);
        // exact cancellation -> +0
        let one = f.from_f64(1.0);
        let mone = f.from_f64(-1.0);
        assert_eq!(add(f, one, mone), pz);
        // -1 * 0 = -0
        assert_eq!(mul(f, mone, pz), nz);
    }

    #[test]
    fn special_values() {
        let f = Format::FP32;
        let inf = f.inf(false);
        let ninf = f.inf(true);
        assert!(f.is_nan(add(f, inf, ninf)));
        assert!(f.is_nan(mul(f, inf, f.zero(false))));
        assert!(f.is_nan(div(f, inf, inf)));
        assert!(f.is_nan(div(f, f.zero(false), f.zero(true))));
        assert_eq!(div(f, f.from_f64(1.0), f.zero(false)), inf);
        assert_eq!(div(f, f.from_f64(-1.0), f.zero(false)), ninf);
    }

    #[test]
    fn subnormal_paths() {
        let f = Format::FP32;
        let min_sub = 1u64; // smallest positive subnormal
        // min_sub + min_sub = 2 * min_sub (exact)
        assert_eq!(add(f, min_sub, min_sub), 2);
        // smallest normal / 2 = largest subnormal region (exact halving)
        let min_norm = 1u64 << 23;
        let half = f.from_f64(0.5);
        assert_eq!(mul(f, min_norm, half), 1u64 << 22);
        // gradual underflow to zero: min_sub * 0.5 -> ties-to-even -> 0
        assert_eq!(mul(f, min_sub, half), 0);
        // 3 * min_sub * 0.5 rounds to 2 * min_sub (tie -> even)
        assert_eq!(mul(f, 3, half), 2);
    }

    #[test]
    fn overflow_to_inf() {
        let f = Format::FP32;
        let max = f32::MAX.to_bits() as u64;
        assert_eq!(add(f, max, max), f.inf(false));
        assert_eq!(mul(f, max, max), f.inf(false));
    }

    #[test]
    fn fp16_spot_values() {
        let f = Format::FP16;
        let one = f.from_f64(1.0);
        assert_eq!(one, 0x3C00);
        let two = add(f, one, one);
        assert_eq!(two, 0x4000);
        // 1/3 in fp16 = 0x3555 (RNE)
        let three = f.from_f64(3.0);
        assert_eq!(div(f, one, three), 0x3555);
        // 65504 is fp16 max; 65504 + 65504 overflows
        let max = f.from_f64(65504.0);
        assert_eq!(max, 0x7BFF);
        assert_eq!(add(f, max, max), f.inf(false));
        // 2048 + 1 = 2048 in fp16 (1 below half ulp)
        let v2048 = f.from_f64(2048.0);
        assert_eq!(add(f, v2048, one), v2048);
    }

    #[test]
    fn fp16_matches_f64_path_through_conversion() {
        // For fp16, doing the op in f64 and converting with one rounding
        // is exact for add/sub/mul (double rounding cannot occur: f64 has
        // > 2*man+2 digits). Validate the generic impl that way.
        let f = Format::FP16;
        let mut rng = Rng::new(77);
        for _ in 0..20_000 {
            let a = rng.float_pattern(5, 10);
            let b = rng.float_pattern(5, 10);
            let (fa, fb) = (f.to_f64(a), f.to_f64(b));
            for (got, host) in [
                (add(f, a, b), fa + fb),
                (sub(f, a, b), fa - fb),
                (mul(f, a, b), fa * fb),
            ] {
                let expect = f.from_f64(host);
                let ok = got == expect || (f.is_nan(got) && host.is_nan());
                assert!(ok, "a={a:#06x} b={b:#06x} got={got:#06x} expect={expect:#06x}");
            }
        }
    }

    #[test]
    fn from_to_f64_roundtrip_fp32() {
        let mut rng = Rng::new(88);
        let f = Format::FP32;
        for _ in 0..10_000 {
            let x = rng.float_pattern(8, 23) as u32;
            let v = f32::from_bits(x);
            if v.is_nan() {
                continue;
            }
            assert_eq!(f.to_f64(x as u64), v as f64);
            assert_eq!(f.from_f64(v as f64) as u32, x);
        }
    }
}
