//! Content-addressed result cache for sweep points.
//!
//! The cache key is a 64-bit FNV-1a hash of the point's canonical
//! configuration JSON ([`SweepPoint::config_json`]); each entry is one
//! JSON file under the cache directory (default `target/sweep-cache/`)
//! holding both the config and the result. Loads verify the stored
//! config against the requested one, so a hash collision (or a manually
//! edited file) degrades to a recompute instead of serving the wrong
//! numbers. Results are pure functions of their config at a fixed
//! [`CONFIG_SCHEMA`](super::point::CONFIG_SCHEMA) — bump that constant
//! when model semantics change so old entries miss.
//!
//! Key derivation is deterministic and content-addressed:
//!
//! ```
//! use convpim::sweep::{Campaign, ResultCache};
//! let points = Campaign::builtin("fig4").unwrap().points();
//! let k0 = ResultCache::key(&points[0].config_json());
//! // Same config → same key; different config → different key.
//! assert_eq!(k0, ResultCache::key(&points[0].config_json()));
//! assert_ne!(k0, ResultCache::key(&points[1].config_json()));
//! assert_eq!(k0.len(), 16); // 64-bit hex
//! ```
//!
//! [`SweepPoint::config_json`]: super::SweepPoint::config_json

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{Context as _, Result};

use super::point::PointResult;
use crate::util::json::Json;

/// 64-bit FNV-1a over a byte string (the offline registry carries no
/// hashing crates; FNV-1a is tiny and good enough for content addressing
/// with a stored-config equality guard behind it).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A directory of `<key>.json` files, one per evaluated sweep point.
#[derive(Clone, Debug)]
pub struct ResultCache {
    dir: PathBuf,
}

impl ResultCache {
    /// Open (without creating) a cache rooted at `dir`. The directory is
    /// created lazily on the first [`ResultCache::store`].
    pub fn new(dir: impl Into<PathBuf>) -> ResultCache {
        ResultCache { dir: dir.into() }
    }

    /// The cache directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Derive the cache key for a canonical config document: the FNV-1a
    /// hash of its compact serialization, as 16 hex digits.
    pub fn key(config: &Json) -> String {
        format!("{:016x}", fnv1a64(config.compact().as_bytes()))
    }

    fn path_for(&self, config: &Json) -> PathBuf {
        self.dir.join(format!("{}.json", Self::key(config)))
    }

    /// Look up a stored result for `config`. Returns `None` on a miss, an
    /// unparsable entry, or a stored config that does not match (hash
    /// collision / stale schema) — all of which mean "recompute".
    pub fn load(&self, config: &Json) -> Option<PointResult> {
        let text = fs::read_to_string(self.path_for(config)).ok()?;
        let doc = Json::parse(&text)?;
        if doc.get("config")? != config {
            return None;
        }
        PointResult::from_json(doc.get("result")?)
    }

    /// Persist a result under its config's key. Writes to a temporary
    /// sibling and renames, so concurrent readers never observe a torn
    /// entry.
    pub fn store(&self, config: &Json, result: &PointResult) -> Result<()> {
        fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating sweep cache dir {:?}", self.dir))?;
        let entry = Json::obj(vec![
            ("config", config.clone()),
            ("result", result.to_json()),
        ]);
        let path = self.path_for(config);
        // Unique-enough temp name: pid + a process-wide counter, so two
        // threads storing the same key never share a temp file.
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, entry.pretty()).with_context(|| format!("writing {tmp:?}"))?;
        fs::rename(&tmp, &path).with_context(|| format!("publishing {path:?}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::Campaign;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "convpim_cache_test_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn fnv_known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn store_load_round_trip() {
        let dir = temp_dir("roundtrip");
        let cache = ResultCache::new(&dir);
        let points = Campaign::builtin("fig4").unwrap().points();
        let p = &points[0];
        let config = p.config_json();
        assert!(cache.load(&config).is_none(), "empty cache must miss");
        let r = p.eval().unwrap();
        cache.store(&config, &r).unwrap();
        assert_eq!(cache.load(&config).unwrap(), r);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_config_is_a_miss() {
        let dir = temp_dir("mismatch");
        let cache = ResultCache::new(&dir);
        let pts = Campaign::builtin("fig4").unwrap().points();
        let (a, b) = (pts[0].config_json(), pts[1].config_json());
        let r = pts[0].eval().unwrap();
        cache.store(&a, &r).unwrap();
        // Forge a collision: copy a's entry onto b's key. The stored
        // config no longer matches the request, so load must miss.
        fs::copy(
            dir.join(format!("{}.json", ResultCache::key(&a))),
            dir.join(format!("{}.json", ResultCache::key(&b))),
        )
        .unwrap();
        assert!(cache.load(&b).is_none());
        assert!(cache.load(&a).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_entry_is_a_miss() {
        let dir = temp_dir("corrupt");
        let cache = ResultCache::new(&dir);
        let points = Campaign::builtin("fig4").unwrap().points();
        let p = &points[0];
        let config = p.config_json();
        cache.store(&config, &p.eval().unwrap()).unwrap();
        let path = dir.join(format!("{}.json", ResultCache::key(&config)));
        fs::write(&path, "{ not json").unwrap();
        assert!(cache.load(&config).is_none());
        let _ = fs::remove_dir_all(&dir);
    }
}
