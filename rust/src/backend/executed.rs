//! [`ExecutedCrossbar`]: bit-exact *executed* evaluation on the crossbar
//! simulator as a [`Backend`].
//!
//! Where [`AnalyticPim`](super::AnalyticPim) predicts, this backend
//! *runs*: a `conv-exec` workload names a model-zoo conv layer and a
//! down-scale factor, and evaluation executes the scaled layer through
//! the im2col conv engine ([`crate::pim::conv`]) with deterministic
//! seeded operands ([`CONV_EXEC_SEED`]), cross-checks the measured
//! per-MAC cycles/gates against the analytic [`CnnPimModel`] prediction,
//! and verifies the output bit-identical to a host nested-loop
//! reference. Evaluation **fails** on any deviation — a passing estimate
//! is a proof, not an observation. The reported throughput is the
//! architecture-scale number backed by those measured per-MAC costs, so
//! it equals the analytic backend's prediction exactly whenever
//! evaluation succeeds.
//!
//! The fixed seed keeps `evaluate` a pure function of
//! `(workload, fmt)` — the property the shared result cache relies on.
//!
//! [`CnnPimModel`]: crate::pim::matpim::CnnPimModel

use anyhow::Result;

use super::{Backend, Estimate};
use crate::metrics;
use crate::pim::conv;
use crate::pim::matpim::NumFmt;
use crate::sweep::campaign::{ArchSpec, WorkloadSpec};
use crate::util::json::Json;

/// Fixed operand seed for executed evaluations: the result must be a
/// pure function of the workload config (cache soundness), so the seed
/// is a constant, not an input. (The `exec-conv` CLI, which *does* take
/// a seed, is a different surface — its seed is part of its cache
/// identity.)
pub const CONV_EXEC_SEED: u64 = 0xC0DE_C04E;

/// The executed-crossbar backend (`pim-exec:SET[@RxC]`).
#[derive(Clone, Debug)]
pub struct ExecutedCrossbar {
    spec: ArchSpec,
    id: String,
}

impl ExecutedCrossbar {
    /// Wrap an architecture axis value.
    pub fn new(spec: ArchSpec) -> ExecutedCrossbar {
        ExecutedCrossbar {
            spec,
            id: format!("pim-exec:{}", spec.name()),
        }
    }
}

impl Backend for ExecutedCrossbar {
    fn id(&self) -> String {
        self.id.clone()
    }

    fn describe(&self) -> String {
        format!(
            "executed crossbar simulation: {:?} gates, im2col conv, measured cycles/gates, \
             bit-exact vs host reference (conv-exec workloads)",
            self.spec.set
        )
    }

    fn supports(&self, workload: &WorkloadSpec) -> bool {
        matches!(workload, WorkloadSpec::ConvExec { .. })
    }

    fn evaluate(&self, workload: &WorkloadSpec, fmt: NumFmt) -> Result<Estimate> {
        let WorkloadSpec::ConvExec { model, conv, scale } = *workload else {
            anyhow::bail!(
                "backend `{}` executes conv-exec workloads only (got `{}`); \
                 use pim:... for the analytic models",
                self.id,
                workload.name()
            );
        };
        if let Some((r, c)) = self.spec.dims {
            anyhow::ensure!(r > 0 && c > 0, "crossbar dims must be positive (got {r}x{c})");
        }
        let arch = self.spec.arch();
        let (_, spec) = super::conv_exec_layer(model, conv, scale)?;
        // Deterministic seeded operands: the executed result must stay a
        // pure function of the workload config (cache soundness), so the
        // seed is a fixed constant.
        let (input, weights) = conv::seeded_operands(&spec, fmt, CONV_EXEC_SEED);
        let run = conv::execute_conv(&spec, fmt, self.spec.set, &input, &weights, arch.rows as usize)?;
        let reference = conv::reference_conv(&spec, fmt, &input, &weights);
        let check = metrics::conv_exec_check(&run, &reference);
        anyhow::ensure!(
            check.passes(),
            "executed conv deviates from the analytic model / host reference: {} \
             (measured {} vs analytic {} cycles/MAC, bit_exact={})",
            check.label,
            check.measured_mac_cycles,
            check.analytic_mac_cycles,
            check.bit_exact
        );
        // Validated: report the architecture-scale MAC throughput (one
        // MAC per row per mac_cycles) — identical to the analytic
        // prediction, which the `passes()` gate above just proved.
        let throughput = arch.throughput_ops(check.analytic_mac_cycles);
        let mut notes = check.to_json();
        if let Json::Obj(m) = &mut notes {
            m.insert("tiles".into(), Json::i(run.tiles as i64));
            m.insert(
                "xbars_per_row".into(),
                Json::i(run.crossbar_span(arch.cols) as i64),
            );
            m.insert("executed".into(), Json::Bool(true));
        }
        Ok(Estimate {
            backend: self.id.clone(),
            workload: workload.name(),
            format: fmt.name(),
            unit: workload.unit().to_string(),
            throughput,
            per_watt: throughput / arch.max_power_w,
            power_w: arch.max_power_w,
            cc: None,
            bytes_per_unit: None,
            notes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::gates::GateSet;
    use crate::sweep::campaign::CnnModel;

    #[test]
    fn rejects_non_conv_exec_workloads() {
        let b = ExecutedCrossbar::new(ArchSpec::paper(GateSet::MemristiveNor));
        let w = WorkloadSpec::from_name("cnn-alexnet").unwrap();
        assert!(!b.supports(&w));
        let err = b.evaluate(&w, NumFmt::Fixed(8)).err().unwrap();
        assert!(format!("{err}").contains("conv-exec workloads only"));
    }

    #[test]
    fn executed_estimate_carries_the_measured_record() {
        // The cheap cell: fixed8, memristive, alexnet conv2 /16.
        let b = ExecutedCrossbar::new(ArchSpec::paper(GateSet::MemristiveNor));
        let w = WorkloadSpec::ConvExec {
            model: CnnModel::AlexNet,
            conv: 2,
            scale: 16,
        };
        let e = b.evaluate(&w, NumFmt::Fixed(8)).unwrap();
        assert_eq!(e.unit, "mac/s");
        assert_eq!(e.notes.get("bit_exact").unwrap().as_bool(), Some(true));
        assert_eq!(e.notes.get("passes").unwrap().as_bool(), Some(true));
        assert_eq!(e.notes.get("executed").unwrap().as_bool(), Some(true));
        // Measured move overhead is visible, not hidden.
        assert!(e.notes.get("move_cycles_per_mac").unwrap().as_f64().unwrap() > 0.0);
        // The executed number equals the analytic prediction exactly —
        // that is the whole point of the construction.
        let analytic = super::super::AnalyticPim::new(ArchSpec::paper(GateSet::MemristiveNor))
            .evaluate(&w, NumFmt::Fixed(8))
            .unwrap();
        assert_eq!(e.throughput, analytic.throughput);
        assert_eq!(e.per_watt, analytic.per_watt);
    }
}
