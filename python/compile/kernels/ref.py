"""Pure-jnp / numpy oracles for the Pallas kernels.

These are the build-time correctness references: pytest compares every
kernel against them (and, for the crossbar arithmetic, against plain
integer arithmetic — the same bit-exact standard the Rust simulator is
held to).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.conv2d.matmul."""
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def conv2d_ref(
    x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int = 0
) -> jnp.ndarray:
    """Reference NCHW convolution via lax.conv_general_dilated."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        (stride, stride),
        [(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def crossbar_step_ref(state: np.ndarray, instr) -> np.ndarray:
    """Reference semantics of one column gate over *packed* uint32 state
    (numpy mirror of kernels.crossbar._apply)."""
    s = state.copy()
    if instr.op == "nor2":
        col = ~(s[:, instr.a] | s[:, instr.b])
    elif instr.op == "nor3":
        col = ~(s[:, instr.a] | s[:, instr.b] | s[:, instr.c])
    elif instr.op == "not":
        col = ~s[:, instr.a]
    elif instr.op == "maj3":
        a, b, c = s[:, instr.a], s[:, instr.b], s[:, instr.c]
        col = (a & b) | (c & (a | b))
    elif instr.op == "copy":
        col = s[:, instr.a]
    elif instr.op == "set0":
        col = np.zeros_like(s[:, 0])
    elif instr.op == "set1":
        col = np.full_like(s[:, 0], 0xFFFFFFFF)
    else:
        raise ValueError(instr.op)
    s[:, instr.out] = col
    return s


def run_program_ref(state: np.ndarray, program) -> np.ndarray:
    """Execute a whole gate program with the numpy reference."""
    s = np.asarray(state, dtype=np.uint32).copy()
    for instr in program:
        s = crossbar_step_ref(s, instr)
    return s
