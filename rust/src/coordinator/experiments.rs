//! The experiment implementations — one per paper table/figure plus the
//! three sensitivity studies from the paper's code repository.

use anyhow::Result;

use super::{Ctx, ExperimentResult, Section};
use crate::gpumodel::{GpuDtype, GpuSpec, Roofline};
use crate::metrics;
use crate::pim::arch::PimArch;
use crate::pim::conv;
use crate::pim::fixed::FixedOp;
use crate::pim::gates::GateSet;
use crate::pim::matpim::{CnnPimModel, NumFmt};
use crate::pim::netexec::{self, NetExecOpts};
use crate::pim::softfloat::Format;
use crate::sweep::{Campaign, PointResult};
use crate::util::json::Json;
use crate::util::si;
use crate::util::table::Table;
use crate::workloads::attention::{decode_workload, DecodeConfig};
use crate::workloads::Workload;

fn tops(x: f64) -> String {
    format!("{:.4}", x / 1e12)
}

fn eng3(x: f64) -> String {
    si(x)
}

/// Measured median seconds for an artifact, if the engine is available.
fn measured_secs(ctx: &mut Ctx, name: &str) -> Option<f64> {
    let iters = ctx.iters();
    let seed = ctx.seed;
    let engine = ctx.engine.as_mut()?;
    let exe = match engine.load(name) {
        Ok(e) => e,
        Err(err) => {
            eprintln!("measured series: cannot load {name}: {err:#}");
            return None;
        }
    };
    let inputs = exe.synth_inputs(seed);
    match exe.timed(&inputs, iters) {
        Ok(t) => Some(t.median_secs()),
        Err(err) => {
            eprintln!("measured series: {name} failed: {err:#}");
            None
        }
    }
}

fn na_or(x: Option<f64>, f: impl Fn(f64) -> String) -> String {
    x.map(f).unwrap_or_else(|| "n/a".into())
}

/// Evaluate a builtin sweep campaign into its point results, failing fast
/// on the first broken point (campaigns here are small and analytic).
fn sweep_results(campaign: &Campaign) -> Result<Vec<PointResult>> {
    campaign.points().iter().map(|p| p.eval()).collect()
}

/// Pick one cell of an evaluated campaign grid. Panics if the cell is
/// missing — for builtin campaigns that is an internal invariant, not an
/// input condition.
fn sweep_cell<'a>(
    results: &'a [PointResult],
    arch: &str,
    format: &str,
    workload: &str,
    gpu_mode: &str,
) -> &'a PointResult {
    results
        .iter()
        .find(|r| {
            r.arch == arch && r.format == format && r.workload == workload && r.gpu_mode == gpu_mode
        })
        .unwrap_or_else(|| {
            panic!("builtin campaign is missing cell ({arch}, {format}, {workload}, {gpu_mode})")
        })
}

// ---------------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------------

/// Table 1: the evaluation parameters of all four systems.
pub fn table1(_ctx: &mut Ctx) -> Result<ExperimentResult> {
    let mut gpu = Table::new(&["parameter", "A6000", "A100"]);
    let (a, b) = (GpuSpec::a6000(), GpuSpec::a100());
    gpu.row(vec!["cores".into(), a.cores.to_string(), b.cores.to_string()]);
    gpu.row(vec![
        "memory".into(),
        format!("{} GB", a.mem_bytes >> 30),
        format!("{} GB", b.mem_bytes >> 30),
    ]);
    gpu.row(vec![
        "memory bandwidth".into(),
        format!("{:.0} GB/s", a.mem_bw / 1e9),
        format!("{:.0} GB/s", b.mem_bw / 1e9),
    ]);
    gpu.row(vec![
        "clock".into(),
        format!("{:.0} MHz", a.clock_hz / 1e6),
        format!("{:.0} MHz", b.clock_hz / 1e6),
    ]);
    gpu.row(vec![
        "max power".into(),
        format!("{:.0} W", a.max_power_w),
        format!("{:.0} W", b.max_power_w),
    ]);

    let mut pim = Table::new(&["parameter", "Memristive PIM", "DRAM PIM"]);
    let (m, d) = (
        PimArch::paper(GateSet::MemristiveNor),
        PimArch::paper(GateSet::DramMaj),
    );
    pim.row(vec![
        "crossbar".into(),
        format!("{}x{}", m.rows, m.cols),
        format!("{}x{}", d.rows, d.cols),
    ]);
    pim.row(vec![
        "memory".into(),
        format!("{} GB", m.mem_bytes >> 30),
        format!("{} GB", d.mem_bytes >> 30),
    ]);
    pim.row(vec![
        "gate energy".into(),
        format!("{:.1} fJ", m.set.costs().gate_energy_j * 1e15),
        format!("{:.0} fJ", d.set.costs().gate_energy_j * 1e15),
    ]);
    pim.row(vec![
        "clock".into(),
        format!("{:.0} MHz", m.clock_hz / 1e6),
        format!("{:.1} MHz", d.clock_hz / 1e6),
    ]);
    pim.row(vec![
        "max power".into(),
        format!("{:.0} W", m.max_power_w),
        format!("{:.0} W", d.max_power_w),
    ]);
    pim.row(vec![
        "crossbars".into(),
        m.num_crossbars().to_string(),
        d.num_crossbars().to_string(),
    ]);
    pim.row(vec![
        "row parallelism R".into(),
        eng3(m.total_rows() as f64),
        eng3(d.total_rows() as f64),
    ]);

    Ok(ExperimentResult {
        id: "table1".into(),
        title: "Evaluation parameters for GPU and PIM systems".into(),
        sections: vec![
            Section {
                caption: "GPU configurations".into(),
                table: gpu,
            },
            Section {
                caption: "PIM configurations (derived quantities included)".into(),
                table: pim,
            },
        ],
        notes: vec![],
        json: Json::obj(vec![(
            "derived_total_rows",
            Json::n(m.total_rows() as f64),
        )]),
    })
}

// ---------------------------------------------------------------------------
// Figure 3
// ---------------------------------------------------------------------------

/// Figure 3: throughput and throughput/W for 32-bit fixed and FP add/mul
/// across all four systems (plus the measured XLA-CPU testbed column).
pub fn fig3(ctx: &mut Ctx) -> Result<ExperimentResult> {
    fig3_for(ctx, GpuSpec::a6000(), NumFmt::Fixed(32), NumFmt::Float(Format::FP32), "fig3")
}

pub(crate) fn fig3_for(
    ctx: &mut Ctx,
    gpu_spec: GpuSpec,
    fixed_fmt: NumFmt,
    float_fmt: NumFmt,
    id: &str,
) -> Result<ExperimentResult> {
    let gpu = Roofline::new(gpu_spec);
    let m = PimArch::paper(GateSet::MemristiveNor);
    let d = PimArch::paper(GateSet::DramMaj);
    let gpu_dtype = if fixed_fmt.bits() <= 16 {
        GpuDtype::F16
    } else {
        GpuDtype::F32
    };

    let mut t = Table::new(&[
        "operation",
        "memristive TOPS",
        "dram TOPS",
        "gpu exp TOPS",
        "gpu theo TOPS",
        "memristive TOPS/W",
        "dram TOPS/W",
        "gpu exp TOPS/W",
        "gpu theo TOPS/W",
    ]);
    let mut json_rows = Vec::new();
    let mut anchors = Vec::new();
    for (fmt, op) in [
        (fixed_fmt, FixedOp::Add),
        (fixed_fmt, FixedOp::Mul),
        (float_fmt, FixedOp::Add),
        (float_fmt, FixedOp::Mul),
    ] {
        let pm = fmt.program(op, GateSet::MemristiveNor);
        let pd = fmt.program(op, GateSet::DramMaj);
        let mem = m.throughput(&pm);
        let dram = d.throughput(&pd);
        let exp = gpu.membound_ops(Roofline::elementwise_bytes(fmt.bits()));
        let theo = gpu.peak(gpu_dtype);
        t.row(vec![
            format!("{} {}", fmt.name(), op.name()),
            tops(mem),
            tops(dram),
            tops(exp),
            tops(theo),
            tops(mem / m.max_power_w),
            tops(dram / d.max_power_w),
            tops(exp / gpu.spec.max_power_w),
            tops(theo / gpu.spec.max_power_w),
        ]);
        json_rows.push(Json::obj(vec![
            ("op", Json::s(format!("{} {}", fmt.name(), op.name()))),
            ("memristive", Json::n(mem)),
            ("dram", Json::n(dram)),
            ("gpu_exp", Json::n(exp)),
            ("gpu_theo", Json::n(theo)),
        ]));
        anchors.push((fmt, op, mem, dram));
    }

    // Measured testbed column (element-wise f32 vectors through PJRT).
    let mut measured = Table::new(&["operation", "testbed XLA-CPU ops/s"]);
    for (name, artifact) in [("f32 add", "elementwise_add_f32"), ("f32 mul", "elementwise_mul_f32")] {
        let secs = measured_secs(ctx, artifact);
        measured.row(vec![
            name.into(),
            na_or(secs.map(|s| (1u64 << 22) as f64 / s), eng3),
        ]);
    }

    let mut notes = vec![format!(
        "paper anchors (memristive): fixed32 add 233 TOPS, fixed32 mul 7.4, fp32 add 33.6, fp32 mul 11.6; \
         dram: 0.35 / 0.01 / 0.05 / 0.02; gpu exp 0.057; gpu theo 38.7"
    )];
    notes.push(
        "re-derived microcode cycle counts reproduce fixed-point anchors exactly and FP anchors \
         within ~2x (our circuits are not AritPIM's hand-optimized ones); see docs/EXPERIMENTS.md §F3"
            .into(),
    );

    Ok(ExperimentResult {
        id: id.into(),
        title: format!(
            "Vectored arithmetic throughput and energy efficiency ({} / {}, GPU {})",
            fixed_fmt.name(),
            float_fmt.name(),
            gpu.spec.name
        ),
        sections: vec![
            Section {
                caption: "paper-scale systems".into(),
                table: t,
            },
            Section {
                caption: "measured on this testbed (validates the memory-bound regime only)"
                    .into(),
                table: measured,
            },
        ],
        notes,
        json: Json::obj(vec![("rows", Json::arr(json_rows))]),
    })
}

// ---------------------------------------------------------------------------
// Figure 4
// ---------------------------------------------------------------------------

/// Figure 4: compute complexity vs improvement over the memory-bound GPU.
///
/// Delegates to the sweep engine: the figure *is* the builtin `fig4`
/// campaign (formats × ops, memristive PIM vs the experimental A6000)
/// rendered as one table — `convpim sweep fig4` streams the same points
/// as CSV/JSONL (docs/EXPERIMENTS.md §F4). Both paths evaluate cells
/// through [`metrics::cc_point`], so the numbers are identical by
/// construction.
pub fn fig4(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let _ = ctx;
    let campaign = Campaign::builtin("fig4").expect("builtin fig4 exists");
    let mut sorted = sweep_results(&campaign)?;
    sorted.sort_by(|a, b| a.cc.partial_cmp(&b.cc).unwrap());

    let mut t = Table::new(&["operation", "CC (gates/bit)", "PIM TOPS", "exp GPU TOPS", "improvement"]);
    let mut json_rows = Vec::new();
    for p in &sorted {
        let op = format!("{} {}", p.format, p.workload.trim_start_matches("elementwise-"));
        let cc = p.cc.expect("elementwise points carry CC");
        t.row(vec![
            op.clone(),
            format!("{cc:.1}"),
            tops(p.pim),
            tops(p.gpu_tp),
            format!("{:.1}x", p.improvement()),
        ]);
        json_rows.push(Json::obj(vec![
            ("op", Json::s(op)),
            ("cc", Json::n(cc)),
            ("improvement", Json::n(p.improvement())),
        ]));
    }

    // Shape check: Spearman-style inverse relation on the sorted list.
    let improvements: Vec<f64> = sorted.iter().map(|p| p.improvement()).collect();
    let inversions = improvements
        .windows(2)
        .filter(|w| w[1] > w[0] * 1.05)
        .count();
    let notes = vec![
        format!(
            "inverse CC-improvement relationship: {} of {} adjacent pairs are non-inverted",
            improvements.len() - 1 - inversions,
            improvements.len() - 1
        ),
        "paper: 16- and 32-bit addition share CC=3 (latency linear in N); multiplication CC grows ~2.5N"
            .into(),
        "generated by the sweep engine (campaign `fig4`): `convpim sweep fig4` streams these \
         points as CSV/JSONL with result caching — docs/EXPERIMENTS.md §F4"
            .into(),
    ];

    Ok(ExperimentResult {
        id: "fig4".into(),
        title: "Compute complexity vs improvement over memory-bound GPU".into(),
        sections: vec![Section {
            caption: "full arithmetic suite (memristive PIM vs experimental A6000)".into(),
            table: t,
        }],
        notes,
        json: Json::obj(vec![("points", Json::arr(json_rows))]),
    })
}

// ---------------------------------------------------------------------------
// Figure 5
// ---------------------------------------------------------------------------

/// Figure 5: batched n×n fp32 matrix multiplication across systems.
///
/// The paper-scale table delegates to the sweep engine (builtin `fig5`
/// campaign: n × {memristive, dram} × {experimental, theoretical A6000});
/// the measured testbed series below still runs through `ctx`. See
/// docs/EXPERIMENTS.md §F5.
pub fn fig5(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let campaign = Campaign::builtin("fig5").expect("builtin fig5 exists");
    let results = sweep_results(&campaign)?;

    let mut t = Table::new(&[
        "n",
        "memristive mm/s",
        "dram mm/s",
        "gpu exp mm/s",
        "gpu theo mm/s",
        "memristive mm/s/W",
        "gpu exp mm/s/W",
    ]);
    let mut json_rows = Vec::new();
    let mut crossover: Option<u64> = None;
    // The n-list lives in one place: the campaign's workload axis.
    for w in &campaign.workloads {
        let crate::sweep::WorkloadSpec::Matmul(n) = *w else {
            continue; // builtin fig5 is matmul-only
        };
        let wl = w.name();
        let mem = sweep_cell(&results, "memristive", "fp32", &wl, "experimental");
        let dram = sweep_cell(&results, "dram", "fp32", &wl, "experimental");
        let theo = sweep_cell(&results, "memristive", "fp32", &wl, "theoretical");
        let (pim, exp, pim_w, exp_w) = (mem.pim, mem.gpu_tp, mem.pim_per_watt, mem.gpu_per_watt);
        if crossover.is_none() && exp_w > pim_w {
            crossover = Some(n);
        }
        t.row(vec![
            n.to_string(),
            eng3(pim),
            eng3(dram.pim),
            eng3(exp),
            eng3(theo.gpu_tp),
            eng3(pim_w),
            eng3(exp_w),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::i(n as i64)),
            ("memristive", Json::n(pim)),
            ("dram", Json::n(dram.pim)),
            ("gpu_exp", Json::n(exp)),
            ("gpu_theo", Json::n(theo.gpu_tp)),
        ]));
    }

    // Measured testbed series: XLA-CPU batched matmuls. The validated
    // *shape* is rising achieved FLOP/s with n (data reuse closing the
    // memory-bound gap) — the same mechanism as the paper's Figure 5.
    let mut measured = Table::new(&["n", "batch", "testbed matmul/s", "testbed GFLOP/s"]);
    let mut meas_flops = Vec::new();
    for (n, batch) in [(16u64, 512u64), (32, 256), (64, 64), (128, 16), (256, 4)] {
        let secs = measured_secs(ctx, &format!("matmul_n{n}"));
        let mmps = secs.map(|s| batch as f64 / s);
        let gflops = mmps.map(|r| r * 2.0 * (n as f64).powi(3) / 1e9);
        if let Some(g) = gflops {
            meas_flops.push(g);
        }
        measured.row(vec![
            n.to_string(),
            batch.to_string(),
            na_or(mmps, eng3),
            na_or(gflops, |g| format!("{g:.2}")),
        ]);
    }

    let mut notes = vec![format!(
        "paper shape: exp/theo GPU gap shrinks as n grows; GPU efficiency overtakes PIM near n=128 \
         (ours: crossover at n={})",
        crossover.map(|n| n.to_string()).unwrap_or_else(|| ">256".into())
    )];
    if meas_flops.len() >= 2 {
        let rising = meas_flops.windows(2).filter(|w| w[1] > w[0]).count();
        notes.push(format!(
            "measured XLA-CPU achieved FLOP/s rises with n in {}/{} steps (reuse closes the \
             memory-bound gap on this testbed too)",
            rising,
            meas_flops.len() - 1
        ));
    }

    Ok(ExperimentResult {
        id: "fig5".into(),
        title: "Batched n×n fp32 matrix multiplication".into(),
        sections: vec![
            Section {
                caption: "paper-scale systems".into(),
                table: t,
            },
            Section {
                caption: "measured on this testbed".into(),
                table: measured,
            },
        ],
        notes,
        json: Json::obj(vec![("rows", Json::arr(json_rows))]),
    })
}

// ---------------------------------------------------------------------------
// Figures 6 and 7
// ---------------------------------------------------------------------------

fn cnn_figure(
    ctx: &mut Ctx,
    id: &str,
    title: &str,
    training: bool,
    gpu_spec: GpuSpec,
    fmt: NumFmt,
    gpu_dtype: GpuDtype,
) -> Result<ExperimentResult> {
    let gpu = Roofline::new(gpu_spec);
    let m_arch = PimArch::paper(GateSet::MemristiveNor);
    let d_arch = PimArch::paper(GateSet::DramMaj);

    let mut t = Table::new(&[
        "model",
        "GMACs",
        "memristive img/s",
        "dram img/s",
        "gpu exp img/s",
        "gpu theo img/s",
        "memristive img/s/W",
        "gpu exp img/s/W",
    ]);
    let mut json_rows = Vec::new();
    let mut gpu_beats_pim_eff = 0;
    let mut models = 0;
    for base in Workload::paper_models() {
        let w = if training { base.training() } else { base };
        let macs = w.total_macs();
        let pim_m = CnnPimModel::new(fmt, GateSet::MemristiveNor, macs);
        let pim_d = CnnPimModel::new(fmt, GateSet::DramMaj, macs);
        let mem = pim_m.throughput(&m_arch);
        let dram = pim_d.throughput(&d_arch);
        let scale = if fmt.bits() == 16 { 0.5 } else { 1.0 }; // fp16 halves traffic
        // Batch-64 roofline: the paper's PyTorch measurements run batched,
        // so weights are amortized and the CNNs sit in the high-reuse
        // regime that pins the experimental GPU near its compute roofline.
        let layers: Vec<(f64, f64)> = w
            .roofline_layers_batched(64.0)
            .iter()
            .map(|&(f, b)| (f, b * scale))
            .collect();
        let exp = gpu.workload_flops(&layers, gpu_dtype) / w.total_flops();
        let theo = gpu.peak(gpu_dtype) / w.total_flops();
        let mem_w = pim_m.throughput_per_watt(&m_arch);
        let exp_w = gpu.per_watt(exp);
        if exp_w > mem_w {
            gpu_beats_pim_eff += 1;
        }
        models += 1;
        t.row(vec![
            w.name.clone(),
            format!("{:.2}", macs / 1e9),
            format!("{mem:.0}"),
            format!("{dram:.3}"),
            format!("{exp:.0}"),
            format!("{theo:.0}"),
            format!("{mem_w:.2}"),
            format!("{exp_w:.2}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", Json::s(w.name.clone())),
            ("macs", Json::n(macs)),
            ("memristive", Json::n(mem)),
            ("dram", Json::n(dram)),
            ("gpu_exp", Json::n(exp)),
            ("gpu_theo", Json::n(theo)),
            ("memristive_per_w", Json::n(mem_w)),
            ("gpu_exp_per_w", Json::n(exp_w)),
        ]));
    }

    // Measured micro-CNN series through PJRT.
    let mut measured = Table::new(&["micro model (64x64, motif)", "testbed img/s"]);
    let arts: Vec<(&str, String)> = if training {
        vec![("alexnet-motif train", "cnn_alexnet_train_step".to_string())]
    } else {
        ["alexnet", "googlenet", "resnet"]
            .iter()
            .map(|m| (*m, format!("cnn_{m}_fwd")))
            .collect()
    };
    for (label, artifact) in arts {
        let secs = measured_secs(ctx, &artifact);
        measured.row(vec![
            label.to_string(),
            na_or(secs.map(|s| 8.0 / s), |x| format!("{x:.1}")),
        ]);
    }

    let notes = vec![
        format!(
            "paper conclusion: digital PIM does not beat the experimental GPU on full-precision \
             CNNs; here the GPU wins on energy efficiency for {gpu_beats_pim_eff}/{models} models"
        ),
        "exp GPU sits near its compute roofline because per-layer OI is high; residual adds and \
         1x1 convolutions pull ResNet/GoogLeNet further from peak than AlexNet (paper §5)"
            .into(),
    ];

    Ok(ExperimentResult {
        id: id.into(),
        title: title.into(),
        sections: vec![
            Section {
                caption: "paper-scale systems (fp32 unless noted)".into(),
                table: t,
            },
            Section {
                caption: "measured micro-CNNs on this testbed (motif validation)".into(),
                table: measured,
            },
        ],
        notes,
        json: Json::obj(vec![("rows", Json::arr(json_rows))]),
    })
}

/// Figure 6: full-precision CNN inference, plus the executed
/// full-network section: end-to-end AlexNet (conv/fc/pool/relu) run
/// bit-exactly on the crossbar simulator, down-scaled, with inter-layer
/// data movement broken out as its own cost bucket.
///
/// Fast contexts execute the cheap fixed8 cells at scale 32 on both gate
/// sets; full runs add the fp32 cell at scale 16 (the figure's precision).
pub fn fig6(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let mut r = cnn_figure(
        ctx,
        "fig6",
        "Full-precision CNN inference throughput and energy efficiency",
        false,
        GpuSpec::a6000(),
        NumFmt::Float(Format::FP32),
        GpuDtype::F32,
    )?;

    let mut cells: Vec<(GateSet, NumFmt, u32)> = vec![
        (GateSet::MemristiveNor, NumFmt::Fixed(8), 32),
        (GateSet::DramMaj, NumFmt::Fixed(8), 32),
    ];
    if !ctx.fast {
        cells.push((GateSet::MemristiveNor, NumFmt::Float(Format::FP32), 16));
    }
    let mut t = Table::new(&[
        "set",
        "format",
        "scale",
        "layers",
        "MACs/img",
        "op cyc/img",
        "move cyc/img",
        "move %",
        "img/s",
        "bit-exact",
    ]);
    let mut json_rows = Vec::new();
    for &(set, fmt, scale) in &cells {
        let graph = netexec::NetGraph::model("alexnet", scale)
            .expect("alexnet has an executable graph");
        let arch = PimArch::paper(set);
        let (inputs, weights) = netexec::seeded_net_operands(&graph, fmt, ctx.seed, 1);
        let opts = NetExecOpts {
            xbar_rows: arch.rows as usize,
            ..NetExecOpts::default()
        };
        let run = netexec::execute_net(&graph, fmt, set, &inputs, &weights, &opts)?;
        let bit_exact =
            run.outputs[0] == netexec::reference_net(&graph, fmt, &inputs[0], &weights);
        anyhow::ensure!(
            bit_exact,
            "executed {} deviates from the host reference ({:?}/{})",
            graph.name,
            set,
            fmt.name()
        );
        // Per-layer cross-validation: every MAC layer's executed per-MAC
        // cost must equal the analytic model the figure is built from.
        for lr in run.layers.iter().filter(|l| l.macs > 0) {
            let model = CnnPimModel::new(fmt, set, lr.macs as f64);
            anyhow::ensure!(
                lr.mac_cycles == model.mac_cycles() && lr.mac_gates == model.mac_gates(),
                "layer {} ({:?}/{}): executed {}/{} per-MAC cycles/gates vs analytic {}/{}",
                lr.name,
                set,
                fmt.name(),
                lr.mac_cycles,
                lr.mac_gates,
                model.mac_cycles(),
                model.mac_gates()
            );
        }
        let tp = arch.throughput_ops(run.total_cycles());
        t.row(vec![
            format!("{set:?}"),
            fmt.name(),
            format!("/{scale}"),
            run.layers.len().to_string(),
            run.macs().to_string(),
            run.op_cycles().to_string(),
            run.move_cycles().to_string(),
            format!("{:.1}", run.move_fraction() * 100.0),
            eng3(tp),
            bit_exact.to_string(),
        ]);
        json_rows.push(Json::obj(vec![
            ("set", Json::s(format!("{set:?}"))),
            ("format", Json::s(fmt.name())),
            ("scale", Json::i(scale as i64)),
            ("macs", Json::i(run.macs() as i64)),
            ("op_cycles", Json::i(run.op_cycles() as i64)),
            ("move_cycles", Json::i(run.move_cycles() as i64)),
            ("stage_bits", Json::i(run.stage_bits() as i64)),
            ("move_fraction", Json::n(run.move_fraction())),
            ("img_per_s", Json::n(tp)),
            ("bit_exact", Json::Bool(bit_exact)),
        ]));
    }
    r.sections.push(Section {
        caption: "executed full network on the crossbar simulator (AlexNet, down-scaled, \
                  bit-exact vs host reference; fast mode runs fixed8 only)"
            .into(),
        table: t,
    });
    r.notes.push(
        "the executed section runs every layer kind — conv/fc MAC microcode plus pooling/ReLU \
         compare/select programs — end to end; `move cyc` and `move %` are the inter-layer \
         staging bucket the figure's upper-bound rows ignore (`convpim exec-net` exposes the \
         same execution; sweep campaign `net-exec` grids it)"
            .into(),
    );
    if let Json::Obj(m) = &mut r.json {
        m.insert("executed_net".into(), Json::arr(json_rows));
    }
    Ok(r)
}

/// Figure 7: full-precision CNN training.
pub fn fig7(ctx: &mut Ctx) -> Result<ExperimentResult> {
    cnn_figure(
        ctx,
        "fig7",
        "Full-precision CNN training throughput and energy efficiency",
        true,
        GpuSpec::a6000(),
        NumFmt::Float(Format::FP32),
        GpuDtype::F32,
    )
}

// ---------------------------------------------------------------------------
// Figure 8
// ---------------------------------------------------------------------------

/// Figure 8: the criteria summary — CC and reuse per workload with the
/// PIM/GPU verdict.
pub fn fig8(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let _ = ctx;
    let fixed_add = NumFmt::Fixed(32).program(FixedOp::Add, GateSet::MemristiveNor);
    let fp_mul = NumFmt::Float(Format::FP32).program(FixedOp::Mul, GateSet::MemristiveNor);
    let cc_add = metrics::compute_complexity(&fixed_add, metrics::io_bits(FixedOp::Add, NumFmt::Fixed(32)));
    let cc_mul = metrics::compute_complexity(&fp_mul, metrics::io_bits(FixedOp::Mul, NumFmt::Float(Format::FP32)));

    let mut rows = vec![
        metrics::classify("vectored fixed32 add", cc_add, 2.0 / 12.0),
        metrics::classify("vectored fp32 mul", cc_mul, 2.0 / 12.0),
    ];
    let mm = 128.0;
    rows.push(metrics::classify(
        "batched matmul n=128 fp32",
        cc_mul,
        mm / 6.0, // OI of an n×n fp32 matmul = n/6
    ));
    let mut zoo = Workload::paper_models();
    zoo.push(crate::workloads::models::vgg16());
    zoo.push(crate::workloads::models::mobilenet_v1());
    for w in zoo {
        rows.push(metrics::classify(
            &format!("{} inference fp32", w.name),
            cc_mul,
            w.reuse_batched(64.0),
        ));
    }
    let dec = decode_workload(DecodeConfig::llama7b(2048));
    rows.push(metrics::classify("LLM attention decode", cc_mul, dec.reuse()));

    let mut t = Table::new(&["workload", "CC (gates/bit)", "reuse (FLOP/byte)", "verdict"]);
    let mut json_rows = Vec::new();
    for r in &rows {
        t.row(vec![
            r.workload.clone(),
            format!("{:.1}", r.cc),
            format!("{:.2}", r.reuse),
            format!("{:?}", r.verdict),
        ]);
        json_rows.push(Json::obj(vec![
            ("workload", Json::s(r.workload.clone())),
            ("cc", Json::n(r.cc)),
            ("reuse", Json::n(r.reuse)),
            ("verdict", Json::s(format!("{:?}", r.verdict))),
        ]));
    }

    Ok(ExperimentResult {
        id: "fig8".into(),
        title: "Criteria indicative of PIM vs traditional computing".into(),
        sections: vec![Section {
            caption: format!(
                "thresholds: CC <= {} or reuse <= {} FLOP/byte favors PIM",
                metrics::CC_THRESHOLD,
                metrics::REUSE_THRESHOLD
            ),
            table: t,
        }],
        notes: vec![
            "paper: CNNs combine high CC and high reuse (GPU side); attention decode is the \
             counter-example the discussion highlights"
                .into(),
        ],
        json: Json::obj(vec![("rows", Json::arr(json_rows))]),
    })
}

// ---------------------------------------------------------------------------
// Sensitivity studies
// ---------------------------------------------------------------------------

/// S1: GPU choice (A100 + extras re-run of the Fig 3/6 cores).
pub fn sens_gpu(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let mut sections = Vec::new();
    let fixed = NumFmt::Fixed(32);
    let flt = NumFmt::Float(Format::FP32);
    let m_arch = PimArch::paper(GateSet::MemristiveNor);
    let add = flt.program(FixedOp::Add, GateSet::MemristiveNor);
    let pim_fp_add = m_arch.throughput(&add);

    let mut t = Table::new(&[
        "gpu",
        "exp elementwise TOPS",
        "theo TOPS",
        "PIM fp32-add improvement",
        "ResNet-50 exp img/s",
        "ResNet-50 theo img/s",
    ]);
    let resnet = crate::workloads::models::resnet50();
    for spec in GpuSpec::all() {
        let gpu = Roofline::new(spec);
        let exp = gpu.membound_ops(Roofline::elementwise_bytes(32));
        let theo = gpu.peak(GpuDtype::F32);
        let exp_img = gpu.workload_flops(&resnet.roofline_layers_batched(64.0), GpuDtype::F32)
            / resnet.total_flops();
        let theo_img = theo / resnet.total_flops();
        t.row(vec![
            spec.name.into(),
            tops(exp),
            tops(theo),
            format!("{:.0}x", pim_fp_add / exp),
            format!("{exp_img:.0}"),
            format!("{theo_img:.0}"),
        ]);
    }
    sections.push(Section {
        caption: "GPU sensitivity (fp32; PIM side unchanged)".into(),
        table: t,
    });
    let _ = (ctx, fixed);

    Ok(ExperimentResult {
        id: "sens-gpu".into(),
        title: "Sensitivity: GPU choice".into(),
        sections,
        notes: vec![
            "paper (code repository): the A100's higher bandwidth shrinks the PIM improvement on \
             memory-bound ops; trends unchanged"
                .into(),
        ],
        json: Json::obj(vec![]),
    })
}

/// S2: 16-bit floating-point quantization.
pub fn sens_fp16(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let mut r = fig3_for(
        ctx,
        GpuSpec::a6000(),
        NumFmt::Fixed(16),
        NumFmt::Float(Format::FP16),
        "sens-fp16",
    )?;
    let cnn = cnn_figure(
        ctx,
        "sens-fp16-cnn",
        "CNN inference at fp16",
        false,
        GpuSpec::a6000(),
        NumFmt::Float(Format::FP16),
        GpuDtype::F16Tensor,
    )?;
    r.title = "Sensitivity: 16-bit precision".into();
    r.sections.extend(cnn.sections);
    r.notes = vec![
        "fp16 lowers PIM gate counts (~4x for mul: 11-bit mantissa) but the GPU tensor cores gain \
         4x too — the paper's conclusion is precision-stable"
            .into(),
    ];
    Ok(r)
}

// ---------------------------------------------------------------------------
// Executed convolution cross-validation
// ---------------------------------------------------------------------------

/// `conv-exec`: one down-scaled model-zoo conv layer *executed* on the
/// crossbar simulator via im2col ([`crate::pim::conv`]) and compared cell
/// by cell against the analytic [`CnnPimModel`] prediction. This is the
/// validation layer beneath Figures 6/7: the analytic per-MAC latency the
/// figures are built from is reproduced exactly by real microcode
/// execution, and the executed output is bit-identical to a host
/// reference. The experiment *fails* (instead of merely noting) on any
/// deviation.
///
/// Fast contexts run the cheap fixed8 cells on both gate sets; full runs
/// add the fp32 cell on the memristive set (the Figure 6 configuration).
pub fn conv_exec(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let workload = crate::workloads::models::alexnet();
    let (layer, full) = workload
        .find_conv("conv2")
        .expect("alexnet has a second conv layer");
    let scale = 16;
    let spec = full.scaled(scale);

    let mut cells: Vec<(GateSet, NumFmt)> = vec![
        (GateSet::MemristiveNor, NumFmt::Fixed(8)),
        (GateSet::DramMaj, NumFmt::Fixed(8)),
    ];
    if !ctx.fast {
        cells.push((GateSet::MemristiveNor, NumFmt::Float(Format::FP32)));
    }

    let mut t = Table::new(&[
        "set",
        "format",
        "MACs",
        "cyc/MAC measured",
        "cyc/MAC analytic",
        "gates/MAC measured",
        "gates/MAC analytic",
        "move cyc/MAC",
        "xbars/row",
        "bit-exact",
    ]);
    let mut json_rows = Vec::new();
    for &(set, fmt) in &cells {
        let arch = PimArch::paper(set);
        let (input, weights) = conv::seeded_operands(&spec, fmt, ctx.seed);
        let run = conv::execute_conv(&spec, fmt, set, &input, &weights, arch.rows as usize)?;
        let reference = conv::reference_conv(&spec, fmt, &input, &weights);
        let check = metrics::conv_exec_check(&run, &reference);
        anyhow::ensure!(
            check.passes(),
            "executed conv deviates from the analytic model: {} \
             (measured {} vs analytic {} cycles/MAC, bit_exact={})",
            check.label,
            check.measured_mac_cycles,
            check.analytic_mac_cycles,
            check.bit_exact
        );
        t.row(vec![
            format!("{set:?}"),
            fmt.name(),
            run.macs.to_string(),
            check.measured_mac_cycles.to_string(),
            check.analytic_mac_cycles.to_string(),
            check.measured_mac_gates.to_string(),
            check.analytic_mac_gates.to_string(),
            format!("{:.1}", check.move_cycles_per_mac),
            run.crossbar_span(arch.cols).to_string(),
            check.bit_exact.to_string(),
        ]);
        json_rows.push(Json::obj(vec![
            ("set", Json::s(format!("{set:?}"))),
            ("format", Json::s(fmt.name())),
            ("macs", Json::i(run.macs as i64)),
            ("mac_cycles_measured", Json::i(check.measured_mac_cycles as i64)),
            ("mac_cycles_analytic", Json::i(check.analytic_mac_cycles as i64)),
            ("mac_gates_measured", Json::i(check.measured_mac_gates as i64)),
            ("mac_gates_analytic", Json::i(check.analytic_mac_gates as i64)),
            ("move_cycles_per_mac", Json::n(check.move_cycles_per_mac)),
            ("move_gates_per_mac", Json::n(run.move_gates_per_mac())),
            ("total_gates_per_mac", Json::n(run.total_gates_per_mac())),
            ("program_width", Json::i(check.program_width as i64)),
            ("crossbar_span", Json::i(run.crossbar_span(arch.cols) as i64)),
            ("bit_exact", Json::Bool(check.bit_exact)),
        ]));
    }

    Ok(ExperimentResult {
        id: "conv-exec".into(),
        title: format!(
            "Executed convolution vs analytic model ({} {} /{scale} -> {})",
            workload.name,
            layer.name,
            spec.label()
        ),
        sections: vec![Section {
            caption: "im2col execution on the crossbar simulator (seeded operands, \
                      bit-exact vs host reference)"
                .into(),
            table: t,
        }],
        notes: vec![
            "measured == analytic per-MAC cost is enforced, not observed: the conv schedule \
             embeds the standard scalar mul/add microcode via column relocation (pim/conv.rs), \
             so Fig. 6/7's per-MAC latencies are backed by executed gates"
                .into(),
            "`move cyc/MAC` quantifies the operand-staging cost the paper's upper-bound model \
             deliberately ignores (§5)"
                .into(),
            "`xbars/row` is how many physical crossbars one row's bit-fields span at the \
             architecture's column width — wide layouts (fp32, large K·K·Cin) are \
             multi-crossbar rows, the analogue of MatPIM's row-footprint spill"
                .into(),
            "full runs add the fp32/memristive cell; fast mode executes the fixed8 cells only"
                .into(),
        ],
        json: Json::obj(vec![("cells", Json::arr(json_rows))]),
    })
}

/// S3: PIM parallelism (crossbar dimension sweep).
///
/// Delegates to the sweep engine: the builtin `sens-dims` campaign puts
/// six crossbar geometries on the architecture axis and picks the
/// (fixed32 elementwise-add, fp32 ResNet-50) cells of the grid. See
/// docs/EXPERIMENTS.md §S3.
pub fn sens_dims(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let _ = ctx;
    let campaign = Campaign::builtin("sens-dims").expect("builtin sens-dims exists");
    let results = sweep_results(&campaign)?;
    let mut t = Table::new(&[
        "crossbar (rows x cols)",
        "total rows R",
        "fixed32-add TOPS",
        "ResNet-50 img/s",
        "max power W",
    ]);
    for spec in &campaign.archs {
        let (rows, cols) = spec.dims.expect("sens-dims archs carry explicit dims");
        let name = spec.name();
        let add = sweep_cell(&results, &name, "fixed32", "elementwise-add", "experimental");
        let cnn = sweep_cell(&results, &name, "fp32", "cnn-resnet50", "experimental");
        let arch = spec.arch();
        t.row(vec![
            format!("{rows}x{cols}"),
            eng3(arch.total_rows() as f64),
            tops(add.pim),
            format!("{:.0}", cnn.pim),
            format!("{:.0}", arch.max_power_w),
        ]);
    }
    Ok(ExperimentResult {
        id: "sens-dims".into(),
        title: "Sensitivity: PIM parallelism (crossbar dimensions)".into(),
        sections: vec![Section {
            caption: "memristive technology, 48 GB memory held constant".into(),
            table: t,
        }],
        notes: vec![
            "R = mem_bits / cols is row-count invariant: taller crossbars do not add parallelism \
             at fixed memory size; narrower columns do (but cap the row bit-field)"
                .into(),
            "generated by the sweep engine (campaign `sens-dims`); `convpim sweep sens-dims` \
             streams the full grid — docs/EXPERIMENTS.md §S3"
                .into(),
        ],
        json: Json::obj(vec![]),
    })
}
