//! Experiment coordinator: the registry and runner that regenerate every
//! table and figure of the paper.
//!
//! Each experiment (one per paper artifact, see DESIGN.md §4) combines
//! three kinds of numbers:
//!
//! * **paper-scale analytic** — the PIM architecture model
//!   ([`crate::pim::arch`]) and GPU rooflines ([`crate::gpumodel`]) at
//!   Table 1 parameters; these are the figures the paper plots;
//! * **measured (testbed)** — real executions of the AOT artifacts
//!   through the PJRT runtime on this machine's CPU backend; these
//!   validate *relative* behaviour (orderings, gap shapes) and are
//!   labelled as testbed numbers, never mixed with paper-scale ones;
//! * **bit-exact validation** — crossbar-simulator runs that gate the
//!   analytic cycle counts behind real executions of the same microcode.
//!
//! The runner renders results as aligned text (console), markdown, CSV
//! and JSON under `results/`.

pub mod experiments;
pub mod report;

use anyhow::Result;

use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::table::Table;

/// Shared context for experiment execution.
pub struct Ctx {
    /// PJRT engine when artifacts are available (measured series);
    /// `None` runs the analytic/validation parts only.
    pub engine: Option<Engine>,
    /// Reduce measured iteration counts (CI mode).
    pub fast: bool,
    /// Random seed for synthesized measured inputs.
    pub seed: u64,
}

impl Ctx {
    /// Build a context, attaching the engine if artifacts exist.
    pub fn new(fast: bool) -> Ctx {
        let engine = match Engine::new() {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("note: measured series disabled ({err:#})");
                None
            }
        };
        Ctx {
            engine,
            fast,
            seed: 0xC0FFEE,
        }
    }

    /// Analytic-only context (no artifacts needed).
    pub fn analytic() -> Ctx {
        Ctx {
            engine: None,
            fast: true,
            seed: 0xC0FFEE,
        }
    }

    /// Measured iterations for a timed run.
    pub fn iters(&self) -> usize {
        if self.fast {
            2
        } else {
            5
        }
    }
}

/// One table within an experiment result.
pub struct Section {
    pub caption: String,
    pub table: Table,
}

/// The output of one experiment.
pub struct ExperimentResult {
    /// Registry id (`fig3`, `table1`, `sens-gpu`, …).
    pub id: String,
    /// Human title (matches the paper artifact).
    pub title: String,
    pub sections: Vec<Section>,
    /// Free-form observations (shape checks, paper-delta notes).
    pub notes: Vec<String>,
    /// Machine-readable payload for results/<id>.json.
    pub json: Json,
}

impl ExperimentResult {
    /// Render for the console.
    pub fn text(&self) -> String {
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        for s in &self.sections {
            out.push_str(&format!("{}\n{}\n", s.caption, s.table.text()));
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Render as markdown.
    pub fn markdown(&self) -> String {
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        for s in &self.sections {
            out.push_str(&format!("**{}**\n\n{}\n", s.caption, s.table.markdown()));
        }
        if !self.notes.is_empty() {
            out.push_str("Notes:\n");
            for n in &self.notes {
                out.push_str(&format!("- {n}\n"));
            }
            out.push('\n');
        }
        out
    }
}

/// All experiment ids, in paper order.
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "table1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "sens-gpu", "sens-fp16",
        "sens-dims",
    ]
}

/// Run one experiment by id.
pub fn run_experiment(id: &str, ctx: &mut Ctx) -> Result<ExperimentResult> {
    match id {
        "table1" => experiments::table1(ctx),
        "fig3" => experiments::fig3(ctx),
        "fig4" => experiments::fig4(ctx),
        "fig5" => experiments::fig5(ctx),
        "fig6" => experiments::fig6(ctx),
        "fig7" => experiments::fig7(ctx),
        "fig8" => experiments::fig8(ctx),
        "sens-gpu" => experiments::sens_gpu(ctx),
        "sens-fp16" => experiments::sens_fp16(ctx),
        "sens-dims" => experiments::sens_dims(ctx),
        other => anyhow::bail!(
            "unknown experiment `{other}`; available: {}",
            all_ids().join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_id_runs_analytically() {
        let mut ctx = Ctx::analytic();
        for id in all_ids() {
            let r = run_experiment(id, &mut ctx).unwrap_or_else(|e| panic!("{id}: {e:#}"));
            assert!(!r.sections.is_empty(), "{id} produced no tables");
            assert!(!r.text().is_empty());
            assert!(!r.markdown().is_empty());
        }
    }

    #[test]
    fn unknown_id_errors() {
        let mut ctx = Ctx::analytic();
        assert!(run_experiment("fig99", &mut ctx).is_err());
    }
}
