//! The experiment implementations — one per paper table/figure plus the
//! three sensitivity studies from the paper's code repository.

use anyhow::Result;

use super::{Ctx, ExperimentResult, Section};
use crate::gpumodel::{GpuDtype, GpuSpec, Roofline};
use crate::metrics;
use crate::pim::arch::PimArch;
use crate::pim::fixed::FixedOp;
use crate::pim::gates::GateSet;
use crate::pim::matpim::{CnnPimModel, MatmulModel, NumFmt};
use crate::pim::softfloat::Format;
use crate::util::json::Json;
use crate::util::si;
use crate::util::table::Table;
use crate::workloads::attention::{decode_workload, DecodeConfig};
use crate::workloads::Workload;

fn tops(x: f64) -> String {
    format!("{:.4}", x / 1e12)
}

fn eng3(x: f64) -> String {
    si(x)
}

/// Measured median seconds for an artifact, if the engine is available.
fn measured_secs(ctx: &mut Ctx, name: &str) -> Option<f64> {
    let iters = ctx.iters();
    let seed = ctx.seed;
    let engine = ctx.engine.as_mut()?;
    let exe = match engine.load(name) {
        Ok(e) => e,
        Err(err) => {
            eprintln!("measured series: cannot load {name}: {err:#}");
            return None;
        }
    };
    let inputs = exe.synth_inputs(seed);
    match exe.timed(&inputs, iters) {
        Ok(t) => Some(t.median_secs()),
        Err(err) => {
            eprintln!("measured series: {name} failed: {err:#}");
            None
        }
    }
}

fn na_or(x: Option<f64>, f: impl Fn(f64) -> String) -> String {
    x.map(f).unwrap_or_else(|| "n/a".into())
}

// ---------------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------------

/// Table 1: the evaluation parameters of all four systems.
pub fn table1(_ctx: &mut Ctx) -> Result<ExperimentResult> {
    let mut gpu = Table::new(&["parameter", "A6000", "A100"]);
    let (a, b) = (GpuSpec::a6000(), GpuSpec::a100());
    gpu.row(vec!["cores".into(), a.cores.to_string(), b.cores.to_string()]);
    gpu.row(vec![
        "memory".into(),
        format!("{} GB", a.mem_bytes >> 30),
        format!("{} GB", b.mem_bytes >> 30),
    ]);
    gpu.row(vec![
        "memory bandwidth".into(),
        format!("{:.0} GB/s", a.mem_bw / 1e9),
        format!("{:.0} GB/s", b.mem_bw / 1e9),
    ]);
    gpu.row(vec![
        "clock".into(),
        format!("{:.0} MHz", a.clock_hz / 1e6),
        format!("{:.0} MHz", b.clock_hz / 1e6),
    ]);
    gpu.row(vec![
        "max power".into(),
        format!("{:.0} W", a.max_power_w),
        format!("{:.0} W", b.max_power_w),
    ]);

    let mut pim = Table::new(&["parameter", "Memristive PIM", "DRAM PIM"]);
    let (m, d) = (
        PimArch::paper(GateSet::MemristiveNor),
        PimArch::paper(GateSet::DramMaj),
    );
    pim.row(vec![
        "crossbar".into(),
        format!("{}x{}", m.rows, m.cols),
        format!("{}x{}", d.rows, d.cols),
    ]);
    pim.row(vec![
        "memory".into(),
        format!("{} GB", m.mem_bytes >> 30),
        format!("{} GB", d.mem_bytes >> 30),
    ]);
    pim.row(vec![
        "gate energy".into(),
        format!("{:.1} fJ", m.set.costs().gate_energy_j * 1e15),
        format!("{:.0} fJ", d.set.costs().gate_energy_j * 1e15),
    ]);
    pim.row(vec![
        "clock".into(),
        format!("{:.0} MHz", m.clock_hz / 1e6),
        format!("{:.1} MHz", d.clock_hz / 1e6),
    ]);
    pim.row(vec![
        "max power".into(),
        format!("{:.0} W", m.max_power_w),
        format!("{:.0} W", d.max_power_w),
    ]);
    pim.row(vec![
        "crossbars".into(),
        m.num_crossbars().to_string(),
        d.num_crossbars().to_string(),
    ]);
    pim.row(vec![
        "row parallelism R".into(),
        eng3(m.total_rows() as f64),
        eng3(d.total_rows() as f64),
    ]);

    Ok(ExperimentResult {
        id: "table1".into(),
        title: "Evaluation parameters for GPU and PIM systems".into(),
        sections: vec![
            Section {
                caption: "GPU configurations".into(),
                table: gpu,
            },
            Section {
                caption: "PIM configurations (derived quantities included)".into(),
                table: pim,
            },
        ],
        notes: vec![],
        json: Json::obj(vec![(
            "derived_total_rows",
            Json::n(m.total_rows() as f64),
        )]),
    })
}

// ---------------------------------------------------------------------------
// Figure 3
// ---------------------------------------------------------------------------

/// Figure 3: throughput and throughput/W for 32-bit fixed and FP add/mul
/// across all four systems (plus the measured XLA-CPU testbed column).
pub fn fig3(ctx: &mut Ctx) -> Result<ExperimentResult> {
    fig3_for(ctx, GpuSpec::a6000(), NumFmt::Fixed(32), NumFmt::Float(Format::FP32), "fig3")
}

pub(crate) fn fig3_for(
    ctx: &mut Ctx,
    gpu_spec: GpuSpec,
    fixed_fmt: NumFmt,
    float_fmt: NumFmt,
    id: &str,
) -> Result<ExperimentResult> {
    let gpu = Roofline::new(gpu_spec);
    let m = PimArch::paper(GateSet::MemristiveNor);
    let d = PimArch::paper(GateSet::DramMaj);
    let gpu_dtype = if fixed_fmt.bits() <= 16 {
        GpuDtype::F16
    } else {
        GpuDtype::F32
    };

    let mut t = Table::new(&[
        "operation",
        "memristive TOPS",
        "dram TOPS",
        "gpu exp TOPS",
        "gpu theo TOPS",
        "memristive TOPS/W",
        "dram TOPS/W",
        "gpu exp TOPS/W",
        "gpu theo TOPS/W",
    ]);
    let mut json_rows = Vec::new();
    let mut anchors = Vec::new();
    for (fmt, op) in [
        (fixed_fmt, FixedOp::Add),
        (fixed_fmt, FixedOp::Mul),
        (float_fmt, FixedOp::Add),
        (float_fmt, FixedOp::Mul),
    ] {
        let pm = fmt.program(op, GateSet::MemristiveNor);
        let pd = fmt.program(op, GateSet::DramMaj);
        let mem = m.throughput(&pm);
        let dram = d.throughput(&pd);
        let exp = gpu.membound_ops(Roofline::elementwise_bytes(fmt.bits()));
        let theo = gpu.peak(gpu_dtype);
        t.row(vec![
            format!("{} {}", fmt.name(), op.name()),
            tops(mem),
            tops(dram),
            tops(exp),
            tops(theo),
            tops(mem / m.max_power_w),
            tops(dram / d.max_power_w),
            tops(exp / gpu.spec.max_power_w),
            tops(theo / gpu.spec.max_power_w),
        ]);
        json_rows.push(Json::obj(vec![
            ("op", Json::s(format!("{} {}", fmt.name(), op.name()))),
            ("memristive", Json::n(mem)),
            ("dram", Json::n(dram)),
            ("gpu_exp", Json::n(exp)),
            ("gpu_theo", Json::n(theo)),
        ]));
        anchors.push((fmt, op, mem, dram));
    }

    // Measured testbed column (element-wise f32 vectors through PJRT).
    let mut measured = Table::new(&["operation", "testbed XLA-CPU ops/s"]);
    for (name, artifact) in [("f32 add", "elementwise_add_f32"), ("f32 mul", "elementwise_mul_f32")] {
        let secs = measured_secs(ctx, artifact);
        measured.row(vec![
            name.into(),
            na_or(secs.map(|s| (1u64 << 22) as f64 / s), eng3),
        ]);
    }

    let mut notes = vec![format!(
        "paper anchors (memristive): fixed32 add 233 TOPS, fixed32 mul 7.4, fp32 add 33.6, fp32 mul 11.6; \
         dram: 0.35 / 0.01 / 0.05 / 0.02; gpu exp 0.057; gpu theo 38.7"
    )];
    notes.push(
        "re-derived microcode cycle counts reproduce fixed-point anchors exactly and FP anchors \
         within ~2x (our circuits are not AritPIM's hand-optimized ones); see EXPERIMENTS.md F3"
            .into(),
    );

    Ok(ExperimentResult {
        id: id.into(),
        title: format!(
            "Vectored arithmetic throughput and energy efficiency ({} / {}, GPU {})",
            fixed_fmt.name(),
            float_fmt.name(),
            gpu.spec.name
        ),
        sections: vec![
            Section {
                caption: "paper-scale systems".into(),
                table: t,
            },
            Section {
                caption: "measured on this testbed (validates the memory-bound regime only)"
                    .into(),
                table: measured,
            },
        ],
        notes,
        json: Json::obj(vec![("rows", Json::arr(json_rows))]),
    })
}

// ---------------------------------------------------------------------------
// Figure 4
// ---------------------------------------------------------------------------

/// Figure 4: compute complexity vs improvement over the memory-bound GPU.
pub fn fig4(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let _ = ctx;
    let arch = PimArch::paper(GateSet::MemristiveNor);
    let gpu = Roofline::new(GpuSpec::a6000());
    let formats = [
        NumFmt::Fixed(8),
        NumFmt::Fixed(16),
        NumFmt::Fixed(32),
        NumFmt::Float(Format::FP16),
        NumFmt::Float(Format::FP32),
        NumFmt::Float(Format::FP64),
    ];
    let ops = FixedOp::all();
    let pts = metrics::cc_sweep(GateSet::MemristiveNor, &arch, &gpu, &formats, &ops);
    let mut sorted = pts.clone();
    sorted.sort_by(|a, b| a.cc.partial_cmp(&b.cc).unwrap());

    let mut t = Table::new(&["operation", "CC (gates/bit)", "PIM TOPS", "exp GPU TOPS", "improvement"]);
    let mut json_rows = Vec::new();
    for p in &sorted {
        t.row(vec![
            format!("{} {}", p.fmt.name(), p.op.name()),
            format!("{:.1}", p.cc),
            tops(p.pim_ops),
            tops(p.gpu_ops),
            format!("{:.1}x", p.improvement()),
        ]);
        json_rows.push(Json::obj(vec![
            ("op", Json::s(format!("{} {}", p.fmt.name(), p.op.name()))),
            ("cc", Json::n(p.cc)),
            ("improvement", Json::n(p.improvement())),
        ]));
    }

    // Shape check: Spearman-style inverse relation on the sorted list.
    let improvements: Vec<f64> = sorted.iter().map(|p| p.improvement()).collect();
    let inversions = improvements
        .windows(2)
        .filter(|w| w[1] > w[0] * 1.05)
        .count();
    let notes = vec![
        format!(
            "inverse CC-improvement relationship: {} of {} adjacent pairs are non-inverted",
            improvements.len() - 1 - inversions,
            improvements.len() - 1
        ),
        "paper: 16- and 32-bit addition share CC=3 (latency linear in N); multiplication CC grows ~2.5N"
            .into(),
    ];

    Ok(ExperimentResult {
        id: "fig4".into(),
        title: "Compute complexity vs improvement over memory-bound GPU".into(),
        sections: vec![Section {
            caption: "full arithmetic suite (memristive PIM vs experimental A6000)".into(),
            table: t,
        }],
        notes,
        json: Json::obj(vec![("points", Json::arr(json_rows))]),
    })
}

// ---------------------------------------------------------------------------
// Figure 5
// ---------------------------------------------------------------------------

/// Figure 5: batched n×n fp32 matrix multiplication across systems.
pub fn fig5(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let gpu = Roofline::new(GpuSpec::a6000());
    let m_arch = PimArch::paper(GateSet::MemristiveNor);
    let d_arch = PimArch::paper(GateSet::DramMaj);
    let fmt = NumFmt::Float(Format::FP32);

    let mut t = Table::new(&[
        "n",
        "memristive mm/s",
        "dram mm/s",
        "gpu exp mm/s",
        "gpu theo mm/s",
        "memristive mm/s/W",
        "gpu exp mm/s/W",
    ]);
    let mut json_rows = Vec::new();
    let mut crossover: Option<u64> = None;
    for n in [8u64, 16, 32, 64, 128, 256] {
        let mm_m = MatmulModel::new(n, fmt, GateSet::MemristiveNor, m_arch.cols);
        let mm_d = MatmulModel::new(n, fmt, GateSet::DramMaj, d_arch.cols);
        let pim = mm_m.throughput(&m_arch);
        let dram = mm_d.throughput(&d_arch);
        let exp = gpu.matmul_throughput(n, GpuDtype::F32);
        let theo = gpu.matmul_throughput_peak(n, GpuDtype::F32);
        let pim_w = mm_m.throughput_per_watt(&m_arch);
        let exp_w = gpu.per_watt(exp);
        if crossover.is_none() && exp_w > pim_w {
            crossover = Some(n);
        }
        t.row(vec![
            n.to_string(),
            eng3(pim),
            eng3(dram),
            eng3(exp),
            eng3(theo),
            eng3(pim_w),
            eng3(exp_w),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::i(n as i64)),
            ("memristive", Json::n(pim)),
            ("dram", Json::n(dram)),
            ("gpu_exp", Json::n(exp)),
            ("gpu_theo", Json::n(theo)),
        ]));
    }

    // Measured testbed series: XLA-CPU batched matmuls. The validated
    // *shape* is rising achieved FLOP/s with n (data reuse closing the
    // memory-bound gap) — the same mechanism as the paper's Figure 5.
    let mut measured = Table::new(&["n", "batch", "testbed matmul/s", "testbed GFLOP/s"]);
    let mut meas_flops = Vec::new();
    for (n, batch) in [(16u64, 512u64), (32, 256), (64, 64), (128, 16), (256, 4)] {
        let secs = measured_secs(ctx, &format!("matmul_n{n}"));
        let mmps = secs.map(|s| batch as f64 / s);
        let gflops = mmps.map(|r| r * 2.0 * (n as f64).powi(3) / 1e9);
        if let Some(g) = gflops {
            meas_flops.push(g);
        }
        measured.row(vec![
            n.to_string(),
            batch.to_string(),
            na_or(mmps, eng3),
            na_or(gflops, |g| format!("{g:.2}")),
        ]);
    }

    let mut notes = vec![format!(
        "paper shape: exp/theo GPU gap shrinks as n grows; GPU efficiency overtakes PIM near n=128 \
         (ours: crossover at n={})",
        crossover.map(|n| n.to_string()).unwrap_or_else(|| ">256".into())
    )];
    if meas_flops.len() >= 2 {
        let rising = meas_flops.windows(2).filter(|w| w[1] > w[0]).count();
        notes.push(format!(
            "measured XLA-CPU achieved FLOP/s rises with n in {}/{} steps (reuse closes the \
             memory-bound gap on this testbed too)",
            rising,
            meas_flops.len() - 1
        ));
    }

    Ok(ExperimentResult {
        id: "fig5".into(),
        title: "Batched n×n fp32 matrix multiplication".into(),
        sections: vec![
            Section {
                caption: "paper-scale systems".into(),
                table: t,
            },
            Section {
                caption: "measured on this testbed".into(),
                table: measured,
            },
        ],
        notes,
        json: Json::obj(vec![("rows", Json::arr(json_rows))]),
    })
}

// ---------------------------------------------------------------------------
// Figures 6 and 7
// ---------------------------------------------------------------------------

fn cnn_figure(
    ctx: &mut Ctx,
    id: &str,
    title: &str,
    training: bool,
    gpu_spec: GpuSpec,
    fmt: NumFmt,
    gpu_dtype: GpuDtype,
) -> Result<ExperimentResult> {
    let gpu = Roofline::new(gpu_spec);
    let m_arch = PimArch::paper(GateSet::MemristiveNor);
    let d_arch = PimArch::paper(GateSet::DramMaj);

    let mut t = Table::new(&[
        "model",
        "GMACs",
        "memristive img/s",
        "dram img/s",
        "gpu exp img/s",
        "gpu theo img/s",
        "memristive img/s/W",
        "gpu exp img/s/W",
    ]);
    let mut json_rows = Vec::new();
    let mut gpu_beats_pim_eff = 0;
    let mut models = 0;
    for base in Workload::paper_models() {
        let w = if training { base.training() } else { base };
        let macs = w.total_macs();
        let pim_m = CnnPimModel::new(fmt, GateSet::MemristiveNor, macs);
        let pim_d = CnnPimModel::new(fmt, GateSet::DramMaj, macs);
        let mem = pim_m.throughput(&m_arch);
        let dram = pim_d.throughput(&d_arch);
        let scale = if fmt.bits() == 16 { 0.5 } else { 1.0 }; // fp16 halves traffic
        // Batch-64 roofline: the paper's PyTorch measurements run batched,
        // so weights are amortized and the CNNs sit in the high-reuse
        // regime that pins the experimental GPU near its compute roofline.
        let layers: Vec<(f64, f64)> = w
            .roofline_layers_batched(64.0)
            .iter()
            .map(|&(f, b)| (f, b * scale))
            .collect();
        let exp = gpu.workload_flops(&layers, gpu_dtype) / w.total_flops();
        let theo = gpu.peak(gpu_dtype) / w.total_flops();
        let mem_w = pim_m.throughput_per_watt(&m_arch);
        let exp_w = gpu.per_watt(exp);
        if exp_w > mem_w {
            gpu_beats_pim_eff += 1;
        }
        models += 1;
        t.row(vec![
            w.name.clone(),
            format!("{:.2}", macs / 1e9),
            format!("{mem:.0}"),
            format!("{dram:.3}"),
            format!("{exp:.0}"),
            format!("{theo:.0}"),
            format!("{mem_w:.2}"),
            format!("{exp_w:.2}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", Json::s(w.name.clone())),
            ("macs", Json::n(macs)),
            ("memristive", Json::n(mem)),
            ("dram", Json::n(dram)),
            ("gpu_exp", Json::n(exp)),
            ("gpu_theo", Json::n(theo)),
            ("memristive_per_w", Json::n(mem_w)),
            ("gpu_exp_per_w", Json::n(exp_w)),
        ]));
    }

    // Measured micro-CNN series through PJRT.
    let mut measured = Table::new(&["micro model (64x64, motif)", "testbed img/s"]);
    let arts: Vec<(&str, String)> = if training {
        vec![("alexnet-motif train", "cnn_alexnet_train_step".to_string())]
    } else {
        ["alexnet", "googlenet", "resnet"]
            .iter()
            .map(|m| (*m, format!("cnn_{m}_fwd")))
            .collect()
    };
    for (label, artifact) in arts {
        let secs = measured_secs(ctx, &artifact);
        measured.row(vec![
            label.to_string(),
            na_or(secs.map(|s| 8.0 / s), |x| format!("{x:.1}")),
        ]);
    }

    let notes = vec![
        format!(
            "paper conclusion: digital PIM does not beat the experimental GPU on full-precision \
             CNNs; here the GPU wins on energy efficiency for {gpu_beats_pim_eff}/{models} models"
        ),
        "exp GPU sits near its compute roofline because per-layer OI is high; residual adds and \
         1x1 convolutions pull ResNet/GoogLeNet further from peak than AlexNet (paper §5)"
            .into(),
    ];

    Ok(ExperimentResult {
        id: id.into(),
        title: title.into(),
        sections: vec![
            Section {
                caption: "paper-scale systems (fp32 unless noted)".into(),
                table: t,
            },
            Section {
                caption: "measured micro-CNNs on this testbed (motif validation)".into(),
                table: measured,
            },
        ],
        notes,
        json: Json::obj(vec![("rows", Json::arr(json_rows))]),
    })
}

/// Figure 6: full-precision CNN inference.
pub fn fig6(ctx: &mut Ctx) -> Result<ExperimentResult> {
    cnn_figure(
        ctx,
        "fig6",
        "Full-precision CNN inference throughput and energy efficiency",
        false,
        GpuSpec::a6000(),
        NumFmt::Float(Format::FP32),
        GpuDtype::F32,
    )
}

/// Figure 7: full-precision CNN training.
pub fn fig7(ctx: &mut Ctx) -> Result<ExperimentResult> {
    cnn_figure(
        ctx,
        "fig7",
        "Full-precision CNN training throughput and energy efficiency",
        true,
        GpuSpec::a6000(),
        NumFmt::Float(Format::FP32),
        GpuDtype::F32,
    )
}

// ---------------------------------------------------------------------------
// Figure 8
// ---------------------------------------------------------------------------

/// Figure 8: the criteria summary — CC and reuse per workload with the
/// PIM/GPU verdict.
pub fn fig8(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let _ = ctx;
    let fixed_add = NumFmt::Fixed(32).program(FixedOp::Add, GateSet::MemristiveNor);
    let fp_mul = NumFmt::Float(Format::FP32).program(FixedOp::Mul, GateSet::MemristiveNor);
    let cc_add = metrics::compute_complexity(&fixed_add, metrics::io_bits(FixedOp::Add, NumFmt::Fixed(32)));
    let cc_mul = metrics::compute_complexity(&fp_mul, metrics::io_bits(FixedOp::Mul, NumFmt::Float(Format::FP32)));

    let mut rows = vec![
        metrics::classify("vectored fixed32 add", cc_add, 2.0 / 12.0),
        metrics::classify("vectored fp32 mul", cc_mul, 2.0 / 12.0),
    ];
    let mm = 128.0;
    rows.push(metrics::classify(
        "batched matmul n=128 fp32",
        cc_mul,
        mm / 6.0, // OI of an n×n fp32 matmul = n/6
    ));
    let mut zoo = Workload::paper_models();
    zoo.push(crate::workloads::models::vgg16());
    zoo.push(crate::workloads::models::mobilenet_v1());
    for w in zoo {
        rows.push(metrics::classify(
            &format!("{} inference fp32", w.name),
            cc_mul,
            w.reuse_batched(64.0),
        ));
    }
    let dec = decode_workload(DecodeConfig::llama7b(2048));
    rows.push(metrics::classify("LLM attention decode", cc_mul, dec.reuse()));

    let mut t = Table::new(&["workload", "CC (gates/bit)", "reuse (FLOP/byte)", "verdict"]);
    let mut json_rows = Vec::new();
    for r in &rows {
        t.row(vec![
            r.workload.clone(),
            format!("{:.1}", r.cc),
            format!("{:.2}", r.reuse),
            format!("{:?}", r.verdict),
        ]);
        json_rows.push(Json::obj(vec![
            ("workload", Json::s(r.workload.clone())),
            ("cc", Json::n(r.cc)),
            ("reuse", Json::n(r.reuse)),
            ("verdict", Json::s(format!("{:?}", r.verdict))),
        ]));
    }

    Ok(ExperimentResult {
        id: "fig8".into(),
        title: "Criteria indicative of PIM vs traditional computing".into(),
        sections: vec![Section {
            caption: format!(
                "thresholds: CC <= {} or reuse <= {} FLOP/byte favors PIM",
                metrics::CC_THRESHOLD,
                metrics::REUSE_THRESHOLD
            ),
            table: t,
        }],
        notes: vec![
            "paper: CNNs combine high CC and high reuse (GPU side); attention decode is the \
             counter-example the discussion highlights"
                .into(),
        ],
        json: Json::obj(vec![("rows", Json::arr(json_rows))]),
    })
}

// ---------------------------------------------------------------------------
// Sensitivity studies
// ---------------------------------------------------------------------------

/// S1: GPU choice (A100 + extras re-run of the Fig 3/6 cores).
pub fn sens_gpu(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let mut sections = Vec::new();
    let fixed = NumFmt::Fixed(32);
    let flt = NumFmt::Float(Format::FP32);
    let m_arch = PimArch::paper(GateSet::MemristiveNor);
    let add = flt.program(FixedOp::Add, GateSet::MemristiveNor);
    let pim_fp_add = m_arch.throughput(&add);

    let mut t = Table::new(&[
        "gpu",
        "exp elementwise TOPS",
        "theo TOPS",
        "PIM fp32-add improvement",
        "ResNet-50 exp img/s",
        "ResNet-50 theo img/s",
    ]);
    let resnet = crate::workloads::models::resnet50();
    for spec in GpuSpec::all() {
        let gpu = Roofline::new(spec);
        let exp = gpu.membound_ops(Roofline::elementwise_bytes(32));
        let theo = gpu.peak(GpuDtype::F32);
        let exp_img = gpu.workload_flops(&resnet.roofline_layers_batched(64.0), GpuDtype::F32)
            / resnet.total_flops();
        let theo_img = theo / resnet.total_flops();
        t.row(vec![
            spec.name.into(),
            tops(exp),
            tops(theo),
            format!("{:.0}x", pim_fp_add / exp),
            format!("{exp_img:.0}"),
            format!("{theo_img:.0}"),
        ]);
    }
    sections.push(Section {
        caption: "GPU sensitivity (fp32; PIM side unchanged)".into(),
        table: t,
    });
    let _ = (ctx, fixed);

    Ok(ExperimentResult {
        id: "sens-gpu".into(),
        title: "Sensitivity: GPU choice".into(),
        sections,
        notes: vec![
            "paper (code repository): the A100's higher bandwidth shrinks the PIM improvement on \
             memory-bound ops; trends unchanged"
                .into(),
        ],
        json: Json::obj(vec![]),
    })
}

/// S2: 16-bit floating-point quantization.
pub fn sens_fp16(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let mut r = fig3_for(
        ctx,
        GpuSpec::a6000(),
        NumFmt::Fixed(16),
        NumFmt::Float(Format::FP16),
        "sens-fp16",
    )?;
    let cnn = cnn_figure(
        ctx,
        "sens-fp16-cnn",
        "CNN inference at fp16",
        false,
        GpuSpec::a6000(),
        NumFmt::Float(Format::FP16),
        GpuDtype::F16Tensor,
    )?;
    r.title = "Sensitivity: 16-bit precision".into();
    r.sections.extend(cnn.sections);
    r.notes = vec![
        "fp16 lowers PIM gate counts (~4x for mul: 11-bit mantissa) but the GPU tensor cores gain \
         4x too — the paper's conclusion is precision-stable"
            .into(),
    ];
    Ok(r)
}

/// S3: PIM parallelism (crossbar dimension sweep).
pub fn sens_dims(ctx: &mut Ctx) -> Result<ExperimentResult> {
    let _ = ctx;
    let fmt = NumFmt::Float(Format::FP32);
    let add32 = NumFmt::Fixed(32).program(FixedOp::Add, GateSet::MemristiveNor);
    let resnet = crate::workloads::models::resnet50();
    let mut t = Table::new(&[
        "crossbar (rows x cols)",
        "total rows R",
        "fixed32-add TOPS",
        "ResNet-50 img/s",
        "max power W",
    ]);
    let mut configs: Vec<(u64, u64)> = vec![(256, 1024), (1024, 1024), (4096, 1024), (65536, 1024)];
    configs.push((1024, 512));
    configs.push((1024, 2048));
    for (rows, cols) in configs {
        let arch = PimArch::with_dims(GateSet::MemristiveNor, rows, cols);
        let cnn = CnnPimModel::new(fmt, GateSet::MemristiveNor, resnet.total_macs());
        t.row(vec![
            format!("{rows}x{cols}"),
            eng3(arch.total_rows() as f64),
            tops(arch.throughput(&add32)),
            format!("{:.0}", cnn.throughput(&arch)),
            format!("{:.0}", arch.max_power_w),
        ]);
    }
    Ok(ExperimentResult {
        id: "sens-dims".into(),
        title: "Sensitivity: PIM parallelism (crossbar dimensions)".into(),
        sections: vec![Section {
            caption: "memristive technology, 48 GB memory held constant".into(),
            table: t,
        }],
        notes: vec![
            "R = mem_bits / cols is row-count invariant: taller crossbars do not add parallelism \
             at fixed memory size; narrower columns do (but cap the row bit-field)"
                .into(),
        ],
        json: Json::obj(vec![]),
    })
}
