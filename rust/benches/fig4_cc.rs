//! Figure 4 regeneration: the compute-complexity sweep (gates/bit vs
//! improvement over the memory-bound GPU) across the full arithmetic
//! suite, timing the sweep generation itself.

use convpim::coordinator::{run_experiment, Ctx};
use convpim::gpumodel::{GpuSpec, Roofline};
use convpim::metrics;
use convpim::pim::arch::PimArch;
use convpim::pim::fixed::FixedOp;
use convpim::pim::gates::GateSet;
use convpim::pim::matpim::NumFmt;
use convpim::pim::softfloat::Format;
use convpim::util::bench::{bench, header, report, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    header("fig4: compute complexity vs improvement");
    let mut ctx = Ctx::analytic();
    let r = run_experiment("fig4", &mut ctx).unwrap();
    println!("{}", r.text());

    header("sweep generation cost");
    let arch = PimArch::paper(GateSet::MemristiveNor);
    let gpu = Roofline::new(GpuSpec::a6000());
    report(bench("cc_sweep (6 formats x 4 ops)", 24.0, &cfg, || {
        let _ = metrics::cc_sweep(
            GateSet::MemristiveNor,
            &arch,
            &gpu,
            &[
                NumFmt::Fixed(8),
                NumFmt::Fixed(16),
                NumFmt::Fixed(32),
                NumFmt::Float(Format::FP16),
                NumFmt::Float(Format::FP32),
                NumFmt::Float(Format::FP64),
            ],
            &FixedOp::all(),
        );
    }));
}
