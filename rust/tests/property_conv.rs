//! Property tests for the executed im2col conv engine: for seeded-random
//! conv shapes (K ∈ {1,3,5,7}, stride 1–2, Cin/Cout 1–8, both gate sets),
//! the crossbar-executed output is **bit-identical** to an independent
//! plain nested-loop host reference, in both fixed-point and
//! softfloat-fp32 arithmetic — and the executed per-MAC compute latency
//! equals the analytic CNN model's exactly.
//!
//! The heavy sweeps are `#[ignore]`d under debug builds (the simulator
//! executes hundreds of thousands of gate instructions per shape); CI
//! runs them via `cargo test --release`, where the whole file takes
//! seconds. A small smoke subset always runs.

use convpim::pim::conv::{conv_program, execute_conv};
use convpim::pim::gates::GateSet;
use convpim::pim::matpim::{scalar_costs, NumFmt};
use convpim::pim::softfloat::{self, Format};
use convpim::pim::xbar::Crossbar;
use convpim::util::rng::Rng;
use convpim::workloads::ConvSpec;

/// The *independent* reference: a plain six-deep nested loop, written
/// directly against the conv definition (not the library's im2col
/// helpers). Wrapping modulo-2^bits fixed-point arithmetic.
fn host_conv_fixed(spec: &ConvSpec, bits: u32, input: &[u64], weights: &[u64]) -> Vec<u64> {
    let mask = (1u64 << bits) - 1;
    let (ho, wo) = spec.out_dims();
    let (cin, h, w, k) = (
        spec.cin as usize,
        spec.h as usize,
        spec.w as usize,
        spec.k as usize,
    );
    let mut out = Vec::new();
    for co in 0..spec.cout as usize {
        for oh in 0..ho as usize {
            for ow in 0..wo as usize {
                let mut acc = 0u64;
                for c in 0..cin {
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oh * spec.stride as usize + ky) as i64 - spec.pad as i64;
                            let ix = (ow * spec.stride as usize + kx) as i64 - spec.pad as i64;
                            let a = if iy < 0 || ix < 0 || iy >= h as i64 || ix >= w as i64 {
                                0
                            } else {
                                input[(c * h + iy as usize) * w + ix as usize]
                            };
                            let b = weights[((co * cin + c) * k + ky) * k + kx];
                            acc = acc.wrapping_add(a.wrapping_mul(b) & mask) & mask;
                        }
                    }
                }
                out.push(acc);
            }
        }
    }
    out
}

/// Same nested loop in softfloat arithmetic, accumulating in the engine's
/// reduction order (channel-major patch, `acc` starting at +0).
fn host_conv_float(spec: &ConvSpec, fmt: Format, input: &[u64], weights: &[u64]) -> Vec<u64> {
    use convpim::pim::fixed::FixedOp;
    let (ho, wo) = spec.out_dims();
    let (cin, h, w, k) = (
        spec.cin as usize,
        spec.h as usize,
        spec.w as usize,
        spec.k as usize,
    );
    let mut out = Vec::new();
    for co in 0..spec.cout as usize {
        for oh in 0..ho as usize {
            for ow in 0..wo as usize {
                let mut acc = 0u64;
                for c in 0..cin {
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oh * spec.stride as usize + ky) as i64 - spec.pad as i64;
                            let ix = (ow * spec.stride as usize + kx) as i64 - spec.pad as i64;
                            let a = if iy < 0 || ix < 0 || iy >= h as i64 || ix >= w as i64 {
                                0
                            } else {
                                input[(c * h + iy as usize) * w + ix as usize]
                            };
                            let b = weights[((co * cin + c) * k + ky) * k + kx];
                            let p = softfloat::apply(fmt, FixedOp::Mul, a, b);
                            acc = softfloat::apply(fmt, FixedOp::Add, acc, p);
                        }
                    }
                }
                out.push(acc);
            }
        }
    }
    out
}

/// Draw a random valid shape: K ∈ {1,3,5,7}, stride 1–2, Cin/Cout 1–8,
/// small spatial dims so one shape executes in milliseconds.
fn random_shape(rng: &mut Rng) -> ConvSpec {
    let k = [1u32, 3, 5, 7][rng.index(4)];
    let pad = rng.index(3) as u32;
    let min_sp = k.saturating_sub(2 * pad).max(1);
    let spec = ConvSpec {
        cin: 1 + rng.index(8) as u32,
        cout: 1 + rng.index(8) as u32,
        h: min_sp + rng.index(4) as u32,
        w: min_sp + rng.index(4) as u32,
        k,
        stride: 1 + rng.index(2) as u32,
        pad,
    };
    assert!(spec.is_valid(), "{spec:?}");
    spec
}

fn check_fixed(spec: &ConvSpec, bits: u32, set: GateSet, rng: &mut Rng) {
    let input = rng.vec_bits((spec.cin * spec.h * spec.w) as usize, bits);
    let weights = rng.vec_bits(spec.cout as usize * spec.patch_len(), bits);
    let fmt = NumFmt::Fixed(bits);
    let run = execute_conv(spec, fmt, set, &input, &weights, 1024).unwrap();
    assert_eq!(
        run.output,
        host_conv_fixed(spec, bits, &input, &weights),
        "fixed{bits} {set:?} {spec:?}"
    );
    let c = scalar_costs(fmt, set);
    assert_eq!(run.mac_cycles, c.mul_cycles + c.add_cycles, "{set:?} {spec:?}");
    assert_eq!(run.mac_gates, c.mul_gates + c.add_gates, "{set:?} {spec:?}");
}

fn check_fp32(spec: &ConvSpec, set: GateSet, rng: &mut Rng) {
    let f = Format::FP32;
    // Finite operands (NaN/Inf propagation is covered by the arithmetic
    // suites; here the interesting property is the MAC chain).
    let gen = |rng: &mut Rng, len: usize| -> Vec<u64> {
        (0..len).map(|_| f.from_f64(rng.f64() * 16.0 - 8.0)).collect()
    };
    let input = gen(rng, (spec.cin * spec.h * spec.w) as usize);
    let weights = gen(rng, spec.cout as usize * spec.patch_len());
    let fmt = NumFmt::Float(f);
    let run = execute_conv(spec, fmt, set, &input, &weights, 1024).unwrap();
    assert_eq!(
        run.output,
        host_conv_float(spec, f, &input, &weights),
        "fp32 {set:?} {spec:?}"
    );
    let c = scalar_costs(fmt, set);
    assert_eq!(run.mac_cycles, c.mul_cycles + c.add_cycles, "{set:?} {spec:?}");
}

/// Smoke subset that always runs, debug builds included.
#[test]
fn prop_conv_smoke() {
    let mut rng = Rng::new(0xC0);
    let spec = ConvSpec { cin: 2, cout: 2, h: 4, w: 4, k: 3, stride: 1, pad: 1 };
    for set in GateSet::all() {
        check_fixed(&spec, 8, set, &mut rng);
    }
    let small = ConvSpec { cin: 1, cout: 1, h: 3, w: 3, k: 3, stride: 1, pad: 1 };
    check_fp32(&small, GateSet::MemristiveNor, &mut rng);
}

/// ~50 seeded-random shapes, fixed-point, both gate sets each.
#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn prop_conv_fixed_random_shapes_bit_exact() {
    let mut rng = Rng::new(0xC1);
    for i in 0..50 {
        let spec = random_shape(&mut rng);
        // 8-bit everywhere; sprinkle 16-bit on the cheaper shapes.
        let bits = if spec.patch_len() <= 80 && i % 3 == 0 { 16 } else { 8 };
        for set in GateSet::all() {
            check_fixed(&spec, bits, set, &mut rng);
        }
    }
}

/// softfloat-fp32 MAC chains on the smaller random shapes, alternating
/// gate sets (fp32 microcode is ~10× the fixed8 gate count).
#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn prop_conv_fp32_random_shapes_bit_exact() {
    let mut rng = Rng::new(0xC2);
    let mut done = 0;
    let mut i = 0;
    while done < 12 {
        i += 1;
        let mut spec = random_shape(&mut rng);
        spec.cout = spec.cout.min(3);
        if spec.patch_len() > 60 || spec.positions() > 40 {
            continue;
        }
        let set = if i % 2 == 0 {
            GateSet::MemristiveNor
        } else {
            GateSet::DramMaj
        };
        check_fp32(&spec, set, &mut rng);
        done += 1;
    }
}

/// The packed `execute` (auto serial/sharded dispatch) and the reference
/// `execute_serial` produce bit-identical state on conv microcode — the
/// same guarantee the arithmetic suites already have, extended to the
/// new program family on a crossbar tall enough to trigger sharding.
#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn prop_conv_packed_execute_matches_serial() {
    let mut rng = Rng::new(0xC3);
    let l = 24;
    let bits = 8;
    let cp = conv_program(NumFmt::Fixed(bits), l, GateSet::MemristiveNor);
    // Tall and not word-aligned: 10k+ rows → 160+ packed words per column,
    // enough for `execute` to take the sharded path.
    let rows = 64 * 160 + 9;
    let mut serial = Crossbar::new(rows, cp.lay.width as usize);
    for t in 0..l {
        serial.write_field(cp.lay.a_col(t, 0), bits, &rng.vec_bits(rows, bits));
        serial.write_field(cp.lay.w_col(t, 0), bits, &vec![rng.bits(bits); rows]);
    }
    let mut sharded = serial.clone();
    serial.execute_serial(&cp.prog);
    sharded.execute(&cp.prog);
    for col in 0..cp.lay.width {
        assert_eq!(
            serial.read_field(col, 1, rows),
            sharded.read_field(col, 1, rows),
            "column {col} diverged"
        );
    }
    assert_eq!(serial.row_gates(), sharded.row_gates());
}
