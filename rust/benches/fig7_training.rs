//! Figure 7 regeneration: CNN training across systems (analytic) plus the
//! measured train-step execution through PJRT.

use convpim::coordinator::{run_experiment, Ctx};
use convpim::runtime::Engine;
use convpim::util::bench::{bench, header, report, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    header("fig7: CNN training");
    let mut ctx = Ctx::new(true);
    let r = run_experiment("fig7", &mut ctx).unwrap();
    println!("{}", r.text());

    header("measured micro-CNN train step (batch 8, XLA-CPU)");
    if let Ok(mut engine) = Engine::new() {
        let exe = engine.load("cnn_alexnet_train_step").unwrap();
        let inputs = exe.synth_inputs(7);
        let _ = exe.run(&inputs).unwrap();
        report(bench("cnn_alexnet_train_step", 8.0, &cfg, || {
            let _ = exe.run(&inputs).unwrap();
        }));
    } else {
        println!("(artifacts not built; analytic series only)");
    }
}
