//! Hot-path microbench: the crossbar column-gate engine (the simulator's
//! inner loop and the §Perf optimization target). Reports simulated
//! row-gates per second across crossbar heights and gate mixes, plus the
//! headline ratios of the engine rewrite, and — with `--out PATH` —
//! writes the machine-readable `BENCH_hotpath.json` artifact that starts
//! the per-PR hotpath perf trajectory (see docs/EXPERIMENTS.md §HOTPATH):
//!
//! * **packed vs scalar** — the bit-sliced engine against the retained
//!   per-row/per-bit `bool` oracle (`pim::oracle::ScalarCrossbar`), same
//!   program, same rows. Packing alone is worth ~64× (one `u64` word op
//!   simulates 64 row-gates); the acceptance bar is ≥ 10×.
//! * **fused vs unfused** — the lowered micro-op pipeline
//!   (`execute_fused`: peephole-fused pairs, widened noalias kernels)
//!   against the retained per-instruction dispatch (`execute_serial`),
//!   single thread, on the nor2-storm and fp32-mul mixes.
//! * **sharded vs serial** — `execute` (fused + sharded across the
//!   thread pool) against the single-thread fused path on a tall
//!   crossbar.
//!
//! Run `cargo bench --bench hotpath_gates -- --out BENCH_hotpath.json`;
//! set `CONVPIM_BENCH_FAST=1` for the CI smoke profile. Exits nonzero if
//! the packed-vs-scalar ratio degenerates below the 10× acceptance bar.

use std::path::PathBuf;
use std::process::ExitCode;

use convpim::pim::fixed::{self, FixedOp};
use convpim::pim::float;
use convpim::pim::gates::GateSet;
use convpim::pim::isa::{Instr, Program};
use convpim::pim::oracle::ScalarCrossbar;
use convpim::pim::softfloat::Format;
use convpim::pim::xbar::Crossbar;
use convpim::util::bench::{bench, header, report, BenchConfig};
use convpim::util::json::Json;
use convpim::util::pool::Pool;
use convpim::util::rng::Rng;

/// A random `gates`-instruction NOR-storm program over `cols` columns.
fn nor_storm(rng: &mut Rng, cols: u32, gates: usize) -> Program {
    let mut prog = Program::new(GateSet::MemristiveNor);
    for _ in 0..gates {
        let a = rng.below(cols as u64) as u32;
        let mut b = rng.below(cols as u64) as u32;
        let mut o = rng.below(cols as u64) as u32;
        while b == a {
            b = rng.below(cols as u64) as u32;
        }
        while o == a || o == b {
            o = rng.below(cols as u64) as u32;
        }
        prog.push(Instr::Nor2 { a, b, out: o });
    }
    prog
}

/// One per-mix JSON record: throughput plus the mix's lowering stats.
fn mix_json(name: &str, rows: usize, prog: &Program, rowgates_per_s: f64) -> Json {
    Json::obj(vec![
        ("name", Json::s(name)),
        ("rows", Json::i(rows as i64)),
        ("gates", Json::i(prog.gates() as i64)),
        ("instrs", Json::i(prog.len() as i64)),
        ("micro_ops", Json::i(prog.lowered().len() as i64)),
        ("fused_pairs", Json::i(prog.lowered().fused() as i64)),
        ("rowgates_per_s", Json::n(rowgates_per_s)),
    ])
}

/// Measure `execute_serial` (unfused dispatch) vs `execute_fused` (the
/// lowered pipeline) on one program; returns (ratio, fused rowgates/s).
fn fused_vs_unfused(
    label: &str,
    prog: &Program,
    rows: usize,
    cfg: &BenchConfig,
) -> (f64, f64) {
    let units = prog.gates() as f64 * rows as f64;
    let mut x = Crossbar::new(rows, prog.width() as usize);
    let runf = report(bench(
        &format!("unfused(serial) {label} rows={rows}"),
        units,
        cfg,
        || x.execute_serial(prog),
    ));
    let rfus = report(bench(
        &format!("fused(lowered)  {label} rows={rows}"),
        units,
        cfg,
        || x.execute_fused(prog),
    ));
    let ratio = runf.per_batch_secs.median / rfus.per_batch_secs.median;
    println!(
        "fused-pipeline speedup over per-instruction dispatch ({label}): \
         {ratio:.2}x  ({} of {} instrs fused into pairs)",
        prog.lowered().fused(),
        prog.len()
    );
    (ratio, rfus.units_per_sec())
}

fn main() -> ExitCode {
    // `--out PATH` writes BENCH_hotpath.json; unknown args (e.g. anything
    // cargo forwards) are ignored.
    let mut out_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--out" {
            out_path = args.next().map(PathBuf::from);
        }
    }

    let cfg = BenchConfig::from_env();
    header("hotpath: crossbar column-gate engine");
    let mut rng = Rng::new(1);
    let mut mixes: Vec<Json> = Vec::new();

    // Raw NOR storm across crossbar heights (auto-dispatched engine).
    for rows in [1024usize, 16384, 262_144] {
        let prog = nor_storm(&mut rng, 64, 1024);
        let mut x = Crossbar::new(rows, 64);
        let units = prog.gates() as f64 * rows as f64;
        let r = report(bench(
            &format!("nor2_storm rows={rows}"),
            units,
            &cfg,
            || x.execute(&prog),
        ));
        mixes.push(mix_json("nor2_storm", rows, &prog, r.units_per_sec()));
    }

    // Real programs: fixed32 add / fp32 add / fp32 mul.
    for (name, prog) in [
        ("fixed32_add", fixed::program(FixedOp::Add, 32, GateSet::MemristiveNor)),
        ("fp32_add", float::program(FixedOp::Add, Format::FP32, GateSet::MemristiveNor)),
        ("fp32_mul", float::program(FixedOp::Mul, Format::FP32, GateSet::MemristiveNor)),
        ("fixed32_add_dram", fixed::program(FixedOp::Add, 32, GateSet::DramMaj)),
    ] {
        let rows = 65_536;
        let mut x = Crossbar::new(rows, prog.width() as usize);
        let units = prog.gates() as f64 * rows as f64;
        let r = report(bench(&format!("{name} rows={rows}"), units, &cfg, || {
            x.execute(&prog)
        }));
        mixes.push(mix_json(name, rows, &prog, r.units_per_sec()));
    }

    // Bit-sliced engine vs the scalar reference oracle (acceptance: ≥10×).
    header("bit-sliced engine vs scalar reference oracle");
    let rows = 4096;
    let prog = nor_storm(&mut rng, 64, 1024);
    let units = prog.gates() as f64 * rows as f64;
    let mut packed = Crossbar::new(rows, 64);
    let mut scalar = ScalarCrossbar::new(rows, 64);
    let rp = report(bench(
        &format!("packed(fused)  nor2_storm rows={rows}"),
        units,
        &cfg,
        || packed.execute_fused(&prog),
    ));
    let rs = report(bench(
        &format!("scalar-oracle  nor2_storm rows={rows}"),
        units,
        &cfg,
        || scalar.execute(&prog),
    ));
    let packed_vs_scalar = rs.per_batch_secs.median / rp.per_batch_secs.median;
    println!(
        "bit-sliced speedup over scalar reference: {packed_vs_scalar:.1}x \
         (acceptance bar: >= 10x)"
    );

    // Fused micro-op pipeline vs the retained per-instruction dispatch.
    header("fused micro-op pipeline vs per-instruction dispatch");
    let storm = nor_storm(&mut rng, 64, 1024);
    let (fused_storm, _) = fused_vs_unfused("nor2_storm", &storm, 65_536, &cfg);
    let fp32_mul = float::program(FixedOp::Mul, Format::FP32, GateSet::MemristiveNor);
    let (fused_fp32, _) = fused_vs_unfused("fp32_mul", &fp32_mul, 65_536, &cfg);

    // Thread-pool sharding vs the single-thread fused path.
    header(&format!(
        "sharded execute vs single-thread fused (pool: {} threads)",
        Pool::global().threads()
    ));
    let rows = 1 << 20;
    let prog = nor_storm(&mut rng, 64, 1024);
    let units = prog.gates() as f64 * rows as f64;
    let mut x = Crossbar::new(rows, 64);
    let rser = report(bench(
        &format!("fused    nor2_storm rows={rows}"),
        units,
        &cfg,
        || x.execute_fused(&prog),
    ));
    let rpar = report(bench(
        &format!("sharded  nor2_storm rows={rows}"),
        units,
        &cfg,
        || x.execute(&prog),
    ));
    let sharded_vs_serial = rser.per_batch_secs.median / rpar.per_batch_secs.median;
    println!("thread-pool speedup over single thread: {sharded_vs_serial:.2}x");

    if let Some(path) = &out_path {
        let doc = Json::obj(vec![
            ("bench", Json::s("hotpath")),
            ("schema", Json::i(1)),
            ("threads", Json::i(Pool::global().threads() as i64)),
            (
                "fast",
                Json::i(i64::from(std::env::var("CONVPIM_BENCH_FAST").is_ok())),
            ),
            ("mixes", Json::arr(mixes)),
            (
                "ratios",
                Json::obj(vec![
                    ("packed_vs_scalar", Json::n(packed_vs_scalar)),
                    ("fused_vs_unfused_nor2_storm", Json::n(fused_storm)),
                    ("fused_vs_unfused_fp32_mul", Json::n(fused_fp32)),
                    ("sharded_vs_serial", Json::n(sharded_vs_serial)),
                ]),
            ),
        ]);
        if let Err(e) = std::fs::write(path, format!("{}\n", doc.pretty())) {
            eprintln!("failed to write {}: {e}", path.display());
            return ExitCode::FAILURE;
        }
        println!("\nwrote {}", path.display());
    }

    if packed_vs_scalar < 10.0 {
        eprintln!(
            "DEGENERATE: packed-vs-scalar ratio {packed_vs_scalar:.1}x \
             below the 10x acceptance bar"
        );
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}
